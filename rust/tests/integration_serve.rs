//! Integration tests over the continuous-batching serving tier
//! (`mdm_cim::serve`): multi-model tenancy, typed overload shedding,
//! bounded tail latency past saturation, the shutdown drain barrier, and
//! bitwise determinism across worker counts.
//!
//! Everything here runs on the pure-Rust path — synthetic models compiled
//! through the pipeline, or local test backends — so no artifacts are
//! required and the suite runs everywhere tier-1 does.

use mdm_cim::crossbar::{TileCost, TileGeometry};
use mdm_cim::rng::Xoshiro256;
use mdm_cim::serve::{
    ModelBackend, ModelSpec, ServeConfig, ServeError, ServeTier, ShedReason, SyntheticModel,
    SyntheticModelConfig, TenantSpec,
};
use mdm_cim::tensor::Tensor;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A deliberately slow doubling backend: makes queues build so shedding
/// and drain behavior are observable without wall-clock flakiness.
#[derive(Debug)]
struct Slow {
    features: usize,
    delay: Duration,
}

impl ModelBackend for Slow {
    fn name(&self) -> &str {
        "slow"
    }
    fn input_features(&self) -> usize {
        self.features
    }
    fn output_features(&self) -> usize {
        self.features
    }
    fn unit_cost(&self) -> TileCost {
        TileCost { adc_conversions: 1, energy_pj: 1.0, ..TileCost::default() }
    }
    fn infer(&self, x: &Tensor) -> mdm_cim::Result<Tensor> {
        std::thread::sleep(self.delay);
        Ok(x.map(|v| v * 2.0))
    }
}

fn slow_spec(features: usize, delay_ms: u64) -> ModelSpec {
    ModelSpec::shared(Arc::new(Slow { features, delay: Duration::from_millis(delay_ms) }))
}

fn synth_cfg() -> SyntheticModelConfig {
    SyntheticModelConfig {
        geometry: TileGeometry::new(16, 32, 8).unwrap(),
        ..SyntheticModelConfig::default()
    }
}

fn input(rng: &mut Xoshiro256, rows: usize, features: usize) -> Tensor {
    let data: Vec<f32> =
        (0..rows * features).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    Tensor::new(&[rows, features], data).unwrap()
}

/// Two resident models serve two concurrent tenants: every admitted
/// request of each tenant is answered by *its* model (logit widths
/// differ-or-match per the model), and per-tenant accounting is isolated.
#[test]
fn two_resident_models_serve_concurrent_tenants() {
    let cfg = synth_cfg();
    let a = Arc::new(SyntheticModel::compile("miniresnet", &cfg).unwrap());
    let b = Arc::new(SyntheticModel::compile("tinyvit", &cfg).unwrap());
    let widths = [a.output_features(), b.output_features()];
    let features = [a.input_features(), b.input_features()];
    let tier = ServeTier::start(
        vec![ModelSpec::shared(a), ModelSpec::shared(b)],
        vec![
            TenantSpec { name: "team-resnet".into(), model: 0, quota: 64 },
            TenantSpec { name: "team-vit".into(), model: 1, quota: 64 },
        ],
        ServeConfig { workers_per_model: 2, wave_rows: 8, shed_rows: 1024 },
    )
    .unwrap();

    let n = 20usize;
    std::thread::scope(|s| {
        for tenant in 0..2usize {
            let tier = &tier;
            s.spawn(move || {
                let mut rng = Xoshiro256::seeded(100 + tenant as u64);
                for _ in 0..n {
                    let rx = tier
                        .submit(tenant, input(&mut rng, 2, features[tenant]))
                        .expect("under quota");
                    let resp = rx.recv().expect("answered");
                    assert_eq!(resp.tenant, tenant);
                    assert_eq!(resp.logits.shape(), &[2, widths[tenant]]);
                }
            });
        }
    });
    let snap = tier.shutdown();
    assert_eq!(snap.completed, 2 * n as u64);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.tenants.len(), 2);
    for t in &snap.tenants {
        assert_eq!(t.submitted, n as u64, "tenant {} accounting leaked", t.name);
        assert_eq!(t.completed, n as u64);
        assert_eq!(t.shed, 0);
    }
    assert!(snap.adc_conversions > 0);
    assert!(snap.energy_pj > 0);
}

/// Quota isolation: a flooding tenant is shed with the *tenant-quota*
/// reason while the well-behaved tenant on the same tier keeps being
/// admitted — one tenant cannot consume another's admission capacity.
#[test]
fn per_tenant_quota_isolation() {
    let tier = ServeTier::start(
        vec![slow_spec(4, 50)],
        vec![
            TenantSpec { name: "greedy".into(), model: 0, quota: 2 },
            TenantSpec { name: "polite".into(), model: 0, quota: 8 },
        ],
        ServeConfig { workers_per_model: 1, wave_rows: 1, shed_rows: 1024 },
    )
    .unwrap();

    // Flood tenant 0 far past its quota of 2.
    let mut greedy_rx = Vec::new();
    let mut greedy_shed = 0usize;
    for _ in 0..12 {
        match tier.submit(0, Tensor::full(&[1, 4], 1.0)) {
            Ok(rx) => greedy_rx.push(rx),
            Err(ServeError::Overloaded { tenant, reason }) => {
                assert_eq!(tenant, 0);
                assert_eq!(reason, ShedReason::TenantQuota);
                greedy_shed += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(greedy_shed >= 10, "quota 2 admitted too much: shed only {greedy_shed}");

    // The other tenant still gets in while the flooder is at quota.
    let polite_rx: Vec<_> = (0..4)
        .map(|_| tier.submit(1, Tensor::full(&[1, 4], 2.0)).expect("isolated quota"))
        .collect();

    for rx in greedy_rx.into_iter().chain(polite_rx) {
        rx.recv().expect("admitted requests are served");
    }
    let snap = tier.shutdown();
    assert_eq!(snap.shed_quota, greedy_shed as u64);
    assert_eq!(snap.shed_queue, 0);
    assert_eq!(snap.tenants[0].shed, greedy_shed as u64);
    assert_eq!(snap.tenants[1].shed, 0);
    assert_eq!(snap.tenants[1].completed, 4);
}

/// Past saturation the tier sheds on queue depth with a typed error — the
/// caller gets `Overloaded` immediately, never a hang — and because the
/// queue is bounded, the p99 latency of what *was* admitted stays bounded
/// too (the tail is capped by queue capacity x service time, not by the
/// offered load).
#[test]
fn overload_sheds_typed_and_keeps_p99_bounded() {
    // Service time ~2ms/wave, wave = 2 rows, at most 8 queued rows: an
    // admitted request waits at most ~4 waves ≈ 10ms + its own service.
    let tier = ServeTier::start(
        vec![slow_spec(4, 2)],
        vec![TenantSpec { name: "flood".into(), model: 0, quota: 100_000 }],
        ServeConfig { workers_per_model: 1, wave_rows: 2, shed_rows: 8 },
    )
    .unwrap();

    let mut shed = 0u64;
    let mut rxs = Vec::new();
    let t0 = Instant::now();
    for _ in 0..300 {
        match tier.submit(0, Tensor::full(&[1, 4], 1.0)) {
            Ok(rx) => rxs.push(rx),
            Err(ServeError::Overloaded { reason, .. }) => {
                assert_eq!(reason, ShedReason::QueueDepth);
                shed += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    let flood_elapsed = t0.elapsed();
    assert!(shed > 0, "300 instant submits at 2ms/wave never tripped the shedder");
    // Shedding answers in microseconds: the whole flood (300 submits, most
    // shed) must take far less than serving 300 requests would.
    assert!(
        flood_elapsed < Duration::from_secs(2),
        "submissions blocked instead of shedding: {flood_elapsed:?}"
    );

    for rx in rxs {
        rx.recv().expect("admitted requests complete");
    }
    let snap = tier.shutdown();
    assert_eq!(snap.shed_queue, shed);
    assert_eq!(snap.completed + shed, 300);
    // Bounded tail: with an 8-row queue bound and ~2ms waves, even a very
    // loaded CI runner stays orders of magnitude under this.
    assert!(
        snap.latency_p99_us < 2_000_000,
        "p99 {}us unbounded past saturation",
        snap.latency_p99_us
    );
}

/// The shutdown drain barrier: every request admitted before `shutdown()`
/// is answered, even when the queues are deep at the moment it is called.
#[test]
fn shutdown_drains_all_admitted_requests() {
    let tier = ServeTier::start(
        vec![slow_spec(4, 5)],
        vec![TenantSpec { name: "t".into(), model: 0, quota: 64 }],
        ServeConfig { workers_per_model: 2, wave_rows: 4, shed_rows: 1024 },
    )
    .unwrap();
    let rxs: Vec<_> = (0..24)
        .map(|_| tier.submit(0, Tensor::full(&[1, 4], 3.0)).unwrap())
        .collect();
    // Shut down immediately — nearly everything is still queued.
    let snap = tier.shutdown();
    assert_eq!(snap.admitted, 24);
    assert_eq!(snap.completed, 24, "drain barrier dropped queued requests");
    for rx in rxs {
        let resp = rx.recv().expect("answered before shutdown returned");
        assert_eq!(resp.logits.data()[0], 6.0);
    }
}

/// Determinism: the same request set produces bitwise-identical logits at
/// 1, 2, and 4 worker threads. Each output row depends only on its own
/// input row, so wave packing and worker scheduling cannot change results.
#[test]
fn results_bitwise_deterministic_across_worker_counts() {
    let model = Arc::new(SyntheticModel::compile("miniresnet", &synth_cfg()).unwrap());
    let features = model.input_features();
    let n = 16usize;
    // Fixed request payloads, regenerated identically per tier.
    let requests: Vec<Tensor> = {
        let mut rng = Xoshiro256::seeded(7);
        (0..n).map(|_| input(&mut rng, 3, features)).collect()
    };

    let mut runs: Vec<Vec<Vec<f32>>> = Vec::new();
    for workers in [1usize, 2, 4] {
        let tier = ServeTier::start(
            vec![ModelSpec::shared(model.clone())],
            vec![TenantSpec { name: "t".into(), model: 0, quota: 1024 }],
            ServeConfig { workers_per_model: workers, wave_rows: 5, shed_rows: 4096 },
        )
        .unwrap();
        let rxs: Vec<_> =
            requests.iter().map(|x| tier.submit(0, x.clone()).unwrap()).collect();
        let logits: Vec<Vec<f32>> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().logits.data().to_vec()).collect();
        tier.shutdown();
        runs.push(logits);
    }
    for (w, run) in runs.iter().enumerate().skip(1) {
        assert_eq!(
            run, &runs[0],
            "logits at {} workers differ bitwise from 1 worker",
            [1, 2, 4][w]
        );
    }
}
