//! Integration tests over the persistent compile-artifact store
//! (rust/DESIGN.md §12): warm starts are bitwise identical to cold
//! compiles at any thread count, corrupted/truncated/stale artifacts
//! degrade to recomputes (never errors), concurrent writers racing on one
//! key all converge to the same bytes, and gc honors budgets without
//! touching protected keys.

use mdm_cim::crossbar::TileGeometry;
use mdm_cim::models::{generate_layer_weights, WeightProfile};
use mdm_cim::parallel::ParallelConfig;
use mdm_cim::pipeline::Pipeline;
use mdm_cim::runtime::{encode_layer, CompileArtifactStore, SCHEMA_VERSION};
use mdm_cim::tensor::Tensor;
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Arc;

/// Fresh per-test scratch directory (pid-suffixed so parallel `cargo test`
/// invocations of different processes never collide).
fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mdm-artifacts-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn small_weights(seed: u64) -> Tensor {
    generate_layer_weights(48, 12, &WeightProfile::cnn(), seed).unwrap()
}

fn pipeline(store: Option<Arc<CompileArtifactStore>>, threads: usize) -> Pipeline {
    Pipeline::new(TileGeometry::new(16, 16, 8).unwrap())
        .strategy("mdm")
        .unwrap()
        .estimator("analytic")
        .unwrap()
        .eta_signed(-2e-3)
        .parallel(ParallelConfig::with_threads(threads))
        .artifact_store_opt(store)
}

#[test]
fn warm_start_is_bitwise_identical_to_cold_at_every_thread_count() {
    let dir = tmp_dir("threads");
    let w = small_weights(7);
    // Cold reference: no store attached, serial.
    let reference = encode_layer(&pipeline(None, 1).compile(&w).unwrap());

    // First iteration compiles cold and publishes; every later iteration
    // (and thread count) must warm-start to the exact same bytes.
    for threads in [1usize, 2, 4, 8] {
        let store = Arc::new(CompileArtifactStore::open(&dir).unwrap());
        let layer = pipeline(Some(store), threads).compile(&w).unwrap();
        assert_eq!(
            encode_layer(&layer),
            reference,
            "store-backed compile diverged at {threads} thread(s)"
        );
    }

    let store = Arc::new(CompileArtifactStore::open(&dir).unwrap());
    let layer = pipeline(Some(store.clone()), 3).compile(&w).unwrap();
    assert_eq!(encode_layer(&layer), reference);
    let st = store.stats();
    assert_eq!((st.hits, st.misses), (1, 0), "restart did not warm-start");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_garbage_and_stale_artifacts_degrade_to_recomputes() {
    let dir = tmp_dir("corrupt");
    let store = Arc::new(CompileArtifactStore::open(&dir).unwrap());
    let p = pipeline(Some(store.clone()), 2);
    let w = small_weights(11);
    let reference = encode_layer(&p.compile(&w).unwrap());
    let path = dir.join(p.layer_key(&w).unwrap().file_name());
    assert!(path.exists(), "cold compile did not publish an artifact");

    // Truncated container: quarantined, recomputed bitwise identical.
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    assert_eq!(encode_layer(&p.compile(&w).unwrap()), reference);
    assert!(store.stats().quarantined >= 1, "truncated artifact was not quarantined");
    assert!(path.exists(), "recompute did not republish after quarantine");

    // Garbage bytes: same degradation.
    std::fs::write(&path, b"definitely not an mdm artifact container").unwrap();
    assert_eq!(encode_layer(&p.compile(&w).unwrap()), reference);
    assert!(store.stats().quarantined >= 2);

    // Stale schema version in an otherwise valid container: evicted (not
    // quarantined), then recomputed and republished at the current version.
    let mut stale = std::fs::read(&path).unwrap();
    stale[4..8].copy_from_slice(&(SCHEMA_VERSION + 1).to_le_bytes());
    std::fs::write(&path, &stale).unwrap();
    let evictions_before = store.stats().evictions;
    assert_eq!(encode_layer(&p.compile(&w).unwrap()), reference);
    assert!(store.stats().evictions > evictions_before, "stale version was not evicted");

    // The republished artifact serves a clean hit again.
    let fresh = Arc::new(CompileArtifactStore::open(&dir).unwrap());
    assert_eq!(encode_layer(&pipeline(Some(fresh.clone()), 2).compile(&w).unwrap()), reference);
    assert_eq!(fresh.stats().hits, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_writers_racing_on_one_key_all_match_the_cold_compile() {
    let dir = tmp_dir("race");
    let w = small_weights(13);
    let reference = encode_layer(&pipeline(None, 1).compile(&w).unwrap());

    // Every thread opens its own store handle on the same directory and
    // compiles the same layer: publication is write-then-rename, so
    // whichever writer lands last leaves a complete, verified artifact.
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let dir = &dir;
                let w = &w;
                let reference = &reference;
                s.spawn(move || {
                    let store = Arc::new(CompileArtifactStore::open(dir).unwrap());
                    let layer = pipeline(Some(store), 1).compile(w).unwrap();
                    assert_eq!(&encode_layer(&layer), reference);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    // Whatever survived the race warm-starts bitwise identically.
    let store = Arc::new(CompileArtifactStore::open(&dir).unwrap());
    assert_eq!(encode_layer(&pipeline(Some(store.clone()), 1).compile(&w).unwrap()), reference);
    let st = store.stats();
    assert_eq!((st.hits, st.misses, st.quarantined), (1, 0, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_honors_budgets_and_never_deletes_protected_keys() {
    let dir = tmp_dir("gc");
    let store = Arc::new(CompileArtifactStore::open(&dir).unwrap());
    let p = pipeline(Some(store.clone()), 1);
    let w_keep = small_weights(17);
    let w_evict = small_weights(18);
    let keep_ref = encode_layer(&p.compile(&w_keep).unwrap());
    p.compile(&w_evict).unwrap();
    let keep_file = p.layer_key(&w_keep).unwrap().file_name();
    let keep: HashSet<String> = [keep_file.clone()].into_iter().collect();

    // Age budget 0 clears everything except the protected key.
    let r = store.gc(None, Some(0), &keep).unwrap();
    assert_eq!((r.scanned, r.removed, r.kept), (2, 1, 1));
    assert!(dir.join(&keep_file).exists(), "gc deleted a protected artifact");

    // The survivor still warm-starts bitwise identically.
    let fresh = Arc::new(CompileArtifactStore::open(&dir).unwrap());
    assert_eq!(
        encode_layer(&pipeline(Some(fresh.clone()), 1).compile(&w_keep).unwrap()),
        keep_ref
    );
    assert_eq!((fresh.stats().hits, fresh.stats().misses), (1, 0));

    // Size budget 0 with nothing protected empties the store.
    let r = store.gc(Some(0), None, &HashSet::new()).unwrap();
    assert_eq!(r.removed, 1);
    assert!(store.list().unwrap().is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}
