//! Integration tests over the observability layer (rust/DESIGN.md §13):
//! pipeline compiles emit stage spans into the ring and per-span duration
//! histograms into the registry, the Prometheus exposition serves them
//! over TCP, the Chrome trace export is well-formed JSON, and a tiny
//! loadtest sweep populates the serve-tier registry mirrors end to end.
//!
//! Span recording is a process-global flag, so every test that toggles it
//! serializes on one lock and filters the ring by its own span names.

use mdm_cim::crossbar::TileGeometry;
use mdm_cim::models::{generate_layer_weights, WeightProfile};
use mdm_cim::pipeline::Pipeline;
use mdm_cim::serve::{self, LoadtestConfig, SyntheticModelConfig};
use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::sync::Mutex;

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

// ---------------------------------------------------------------- histogram

#[test]
fn histogram_empty_single_and_boundaries() {
    let h = mdm_cim::obs::Histogram::default();
    assert_eq!(h.count(), 0);
    assert_eq!(h.percentile(50.0), 0);
    assert_eq!(h.mean(), 0.0);

    h.record(77);
    for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
        assert_eq!(h.percentile(p), 77, "p{p} of a single sample");
    }
    assert_eq!(h.mean(), 77.0);

    // Legacy LatencyRecorder nearest-rank semantics, now served by the one
    // shared implementation (the coordinator's alias points here too).
    let h = mdm_cim::obs::Histogram::default();
    for us in (10..=100).step_by(10) {
        h.record(us);
    }
    assert_eq!(h.percentile(50.0), 60);
    assert_eq!(h.percentile(100.0), 100);
}

#[test]
fn concurrent_counter_increments_sum_exactly() {
    let c = mdm_cim::obs::counter("it.obs.concurrent");
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                for _ in 0..25_000 {
                    c.inc();
                }
            });
        }
    });
    assert_eq!(c.get(), 200_000);
}

// ------------------------------------------------------------------- spans

#[test]
fn pipeline_compile_emits_stage_spans_and_duration_histograms() {
    let _g = lock();
    mdm_cim::obs::set_enabled(true);
    mdm_cim::obs::span::clear();

    let w = generate_layer_weights(48, 12, &WeightProfile::cnn(), 7).unwrap();
    let pipeline = Pipeline::new(TileGeometry::new(16, 16, 8).unwrap())
        .strategy("mdm")
        .unwrap()
        .estimator("analytic")
        .unwrap();
    pipeline.compile(&w).unwrap();
    mdm_cim::obs::set_enabled(false);

    let (events, _) = mdm_cim::obs::span::snapshot();
    for stage in ["compile.layer", "compile.quantize", "compile.tile", "compile.map"] {
        assert!(
            events.iter().any(|e| e.name == stage),
            "missing span {stage} in {:?}",
            events.iter().map(|e| e.name).collect::<Vec<_>>()
        );
        let h = mdm_cim::obs::histogram(&format!("span_duration_us{{span=\"{stage}\"}}"));
        assert!(h.count() >= 1, "no duration samples for {stage}");
    }
    // Two sign parts compile per layer.
    assert!(events.iter().filter(|e| e.name == "compile.quantize").count() >= 2);
}

// ------------------------------------------------- trace-JSON well-formedness

/// Minimal strict JSON validator (objects, arrays, strings, numbers,
/// bools, null) — enough to prove the trace loads in a real parser.
fn validate_json(s: &str) -> Result<(), String> {
    let b = s.as_bytes();
    let mut i = 0usize;
    fn ws(b: &[u8], i: &mut usize) {
        while *i < b.len() && b[*i].is_ascii_whitespace() {
            *i += 1;
        }
    }
    fn value(b: &[u8], i: &mut usize) -> Result<(), String> {
        ws(b, i);
        match b.get(*i) {
            Some(b'{') => {
                *i += 1;
                ws(b, i);
                if b.get(*i) == Some(&b'}') {
                    *i += 1;
                    return Ok(());
                }
                loop {
                    ws(b, i);
                    string(b, i)?;
                    ws(b, i);
                    if b.get(*i) != Some(&b':') {
                        return Err(format!("expected ':' at {i}"));
                    }
                    *i += 1;
                    value(b, i)?;
                    ws(b, i);
                    match b.get(*i) {
                        Some(b',') => *i += 1,
                        Some(b'}') => {
                            *i += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("expected ',' or '}}' at {i}")),
                    }
                }
            }
            Some(b'[') => {
                *i += 1;
                ws(b, i);
                if b.get(*i) == Some(&b']') {
                    *i += 1;
                    return Ok(());
                }
                loop {
                    value(b, i)?;
                    ws(b, i);
                    match b.get(*i) {
                        Some(b',') => *i += 1,
                        Some(b']') => {
                            *i += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("expected ',' or ']' at {i}")),
                    }
                }
            }
            Some(b'"') => string(b, i),
            Some(b't') => literal(b, i, "true"),
            Some(b'f') => literal(b, i, "false"),
            Some(b'n') => literal(b, i, "null"),
            Some(c) if c.is_ascii_digit() || *c == b'-' => {
                *i += 1;
                while *i < b.len()
                    && (b[*i].is_ascii_digit() || matches!(b[*i], b'.' | b'e' | b'E' | b'+' | b'-'))
                {
                    *i += 1;
                }
                Ok(())
            }
            other => Err(format!("unexpected {other:?} at {i}")),
        }
    }
    fn string(b: &[u8], i: &mut usize) -> Result<(), String> {
        if b.get(*i) != Some(&b'"') {
            return Err(format!("expected '\"' at {i}"));
        }
        *i += 1;
        while let Some(&c) = b.get(*i) {
            match c {
                b'\\' => *i += 2,
                b'"' => {
                    *i += 1;
                    return Ok(());
                }
                _ => *i += 1,
            }
        }
        Err("unterminated string".into())
    }
    fn literal(b: &[u8], i: &mut usize, lit: &str) -> Result<(), String> {
        if b[*i..].starts_with(lit.as_bytes()) {
            *i += lit.len();
            Ok(())
        } else {
            Err(format!("bad literal at {i}"))
        }
    }
    value(b, &mut i)?;
    ws(b, &mut i);
    if i != b.len() {
        return Err(format!("trailing bytes at {i} of {}", b.len()));
    }
    Ok(())
}

#[test]
fn trace_export_is_valid_chrome_json() {
    let _g = lock();
    mdm_cim::obs::set_enabled(true);
    mdm_cim::obs::span::clear();
    {
        let _outer = mdm_cim::span!("it.obs.outer");
        let _inner = mdm_cim::span!("it.obs.inner", "k={}", 3);
    }
    mdm_cim::obs::set_enabled(false);

    let json = mdm_cim::obs::span::trace_json();
    validate_json(&json).unwrap_or_else(|e| panic!("invalid trace JSON ({e}):\n{json}"));
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"ph\": \"X\""));
    assert!(json.contains("\"it.obs.inner\""));
    assert!(json.contains("\"detail\": \"k=3\""));

    // write_trace lands the same bytes on disk.
    let dir = std::env::temp_dir().join(format!("mdm-obs-it-{}", std::process::id()));
    let path = dir.join("trace.json");
    mdm_cim::obs::span::write_trace(&path).unwrap();
    let on_disk = std::fs::read_to_string(&path).unwrap();
    validate_json(&on_disk).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn json_validator_rejects_garbage() {
    assert!(validate_json("{\"a\": 1}").is_ok());
    assert!(validate_json("[1, 2.5, -3e4, \"x\", true, null]").is_ok());
    assert!(validate_json("{\"a\": }").is_err());
    assert!(validate_json("{\"a\": 1").is_err());
    assert!(validate_json("[1,]").is_err());
    assert!(validate_json("{} trailing").is_err());
}

// ------------------------------------------------------------- exposition

#[test]
fn prometheus_scrape_serves_counters_and_span_histograms() {
    let _g = lock();
    mdm_cim::obs::set_enabled(true);
    {
        let _sp = mdm_cim::span!("it.obs.scrape");
    }
    mdm_cim::obs::set_enabled(false);
    mdm_cim::obs::counter("it.obs.scrape.hits{tenant=\"a\"}").add(5);

    let server = mdm_cim::obs::MetricsServer::start("127.0.0.1:0").unwrap();
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut body = String::new();
    stream.read_to_string(&mut body).unwrap();

    assert!(body.starts_with("HTTP/1.1 200 OK"), "got:\n{body}");
    assert!(body.contains("mdm_it_obs_scrape_hits{tenant=\"a\"} 5"), "got:\n{body}");
    // The span duration histogram renders as a labeled histogram family.
    assert!(body.contains("# TYPE mdm_span_duration_us histogram"), "got:\n{body}");
    assert!(
        body.contains("mdm_span_duration_us_bucket{span=\"it.obs.scrape\",le=\"+Inf\"}"),
        "got:\n{body}"
    );
    assert!(body.contains("mdm_span_duration_us_count{span=\"it.obs.scrape\"}"), "got:\n{body}");
}

// ------------------------------------------------------- serve-tier mirrors

#[test]
fn loadtest_smoke_populates_registry_and_trace_end_to_end() {
    let _g = lock();
    mdm_cim::obs::set_enabled(true);
    mdm_cim::obs::span::clear();

    let cfg = LoadtestConfig {
        models: vec!["miniresnet".into()],
        rates: vec![200.0],
        duration_ms: 120,
        closed_clients: 1,
        synth: SyntheticModelConfig {
            geometry: TileGeometry::new(16, 32, 8).unwrap(),
            ..SyntheticModelConfig::default()
        },
        ..LoadtestConfig::default()
    };
    let report = serve::run_loadtest(&cfg).unwrap();
    mdm_cim::obs::set_enabled(false);
    assert!(report.open_loop[0].snap.completed > 0);

    // Registry mirrors of the tier counters.
    assert!(mdm_cim::obs::counter("serve.waves").get() > 0);
    assert!(mdm_cim::obs::counter("serve.completed").get() > 0);
    assert!(
        mdm_cim::obs::counter("serve.tenant.completed{tenant=\"miniresnet\"}").get() > 0
    );
    assert!(mdm_cim::obs::histogram("serve.latency_us").count() > 0);
    assert!(
        mdm_cim::obs::histogram("serve.tenant.latency_us{tenant=\"miniresnet\"}").count() > 0
    );

    // The trace covers compile stages, the circuit probe, and serve waves.
    let (events, _) = mdm_cim::obs::span::snapshot();
    for stage in ["compile.map", "loadtest.circuit_probe", "solve.circuit", "serve.wave"] {
        assert!(
            events.iter().any(|e| e.name == stage),
            "missing span {stage} in {:?}",
            events.iter().map(|e| e.name).collect::<Vec<_>>()
        );
    }
    let json = mdm_cim::obs::span::trace_json();
    validate_json(&json).unwrap_or_else(|e| panic!("invalid trace JSON ({e})"));
}
