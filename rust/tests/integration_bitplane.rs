//! Differential property suite for the packed bit-plane NF kernels
//! (`nf::packed`): across randomized shapes (including ragged widths),
//! densities, and parasitic ratios, every packed kernel must reproduce the
//! scalar reference in `nf` **bit for bit** — the aggregates are exact
//! integer sums, so there is no tolerance, not even 1 ULP (see the
//! `nf::packed` module docs for the exactness argument). No artifacts
//! required.

use mdm_cim::nf::estimator::{estimator_by_name, Analytic, NfEstimator};
use mdm_cim::nf::packed::PackedPlanes;
use mdm_cim::nf::{
    active_count, aggregate_manhattan, manhattan_nf_mean, manhattan_nf_per_col, manhattan_nf_sum,
};
use mdm_cim::rng::Xoshiro256;
use mdm_cim::tensor::Tensor;
use mdm_cim::testsupport::{
    low_order_dense_densities, propcheck, random_bit_sliced_planes, PropConfig,
};
use mdm_cim::CrossbarPhysics;

fn random_planes(rows: usize, cols: usize, density: f64, rng: &mut Xoshiro256) -> Tensor {
    let data: Vec<f32> =
        (0..rows * cols).map(|_| if rng.bernoulli(density) { 1.0 } else { 0.0 }).collect();
    Tensor::new(&[rows, cols], data).unwrap()
}

/// Assert every packed kernel output is bitwise equal to its scalar
/// reference on `t` at `ratio`; returns an error message for `propcheck`.
fn check_bitwise(t: &Tensor, ratio: f64) -> Result<(), String> {
    let p = PackedPlanes::from_tensor(t).map_err(|e| e.to_string())?;
    if p.active_count() != active_count(t) as u64 {
        return Err(format!("active_count {} vs {}", p.active_count(), active_count(t)));
    }
    if p.aggregate_manhattan() as f64 != aggregate_manhattan(t) {
        return Err(format!(
            "aggregate {} vs {}",
            p.aggregate_manhattan(),
            aggregate_manhattan(t)
        ));
    }
    let (ps, ss) = (p.nf_sum(ratio), manhattan_nf_sum(t, ratio));
    if ps.to_bits() != ss.to_bits() {
        return Err(format!("nf_sum {ps} vs {ss}"));
    }
    let (pm, sm) = (p.nf_mean(ratio), manhattan_nf_mean(t, ratio));
    if pm.to_bits() != sm.to_bits() {
        return Err(format!("nf_mean {pm} vs {sm}"));
    }
    let per = p.nf_per_col(ratio);
    let reference = manhattan_nf_per_col(t, ratio);
    if per.len() != reference.len() {
        return Err(format!("per_col len {} vs {}", per.len(), reference.len()));
    }
    for (k, (a, b)) in per.iter().zip(&reference).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!("nf_per_col[{k}] {a} vs {b}"));
        }
    }
    Ok(())
}

/// Property: packed nf_sum/nf_mean/nf_per_col are bitwise equal to the
/// scalar reference over random shapes — widths deliberately straddle the
/// 64-bit word boundary (ragged last words) — densities, and log-ranged
/// parasitic ratios.
#[test]
fn packed_kernels_bitwise_equal_scalar_reference() {
    propcheck(
        PropConfig { cases: 96, seed: 0xB17_0001, max_size: 48 },
        |rng, size| {
            let rows = 1 + rng.below(size as u64) as usize;
            // Widths cluster around the u64 word boundary: 1..=128+size.
            let cols = 1 + rng.below((128 + size) as u64) as usize;
            let density = rng.uniform_range(0.0, 1.0);
            let ratio = 10f64.powf(rng.uniform_range(-8.0, 0.0));
            (random_planes(rows, cols, density, rng), ratio)
        },
        |(t, ratio)| check_bitwise(t, *ratio),
    );
}

/// Explicit edge shapes: all-zero and all-one planes at widths on both
/// sides of (and exactly at) the word boundary, plus single-row and
/// single-column tiles.
#[test]
fn edge_shapes_all_zero_and_all_one() {
    let ratio = 2.5 / 300e3;
    for rows in [1usize, 2, 16] {
        for cols in [1usize, 63, 64, 65, 100, 127, 128, 129] {
            let zero = Tensor::zeros(&[rows, cols]);
            check_bitwise(&zero, ratio).unwrap();
            assert_eq!(PackedPlanes::from_tensor(&zero).unwrap().active_count(), 0);
            let one = Tensor::full(&[rows, cols], 1.0);
            check_bitwise(&one, ratio).unwrap();
            assert_eq!(
                PackedPlanes::from_tensor(&one).unwrap().active_count(),
                (rows * cols) as u64
            );
        }
    }
}

/// The registry backends `packed` and `incremental` (and their aliases)
/// are bitwise equal to `analytic` through the `NfEstimator` interface.
#[test]
fn packed_estimators_match_analytic_through_the_registry() {
    let physics = CrossbarPhysics::default();
    let mut rng = Xoshiro256::seeded(0xB17_0002);
    let tiles: Vec<Tensor> = (0..6)
        .map(|i| random_planes(4 + 3 * i, 30 + 17 * i, 0.1 + 0.12 * i as f64, &mut rng))
        .collect();
    for name in ["packed", "bitplane", "incremental", "delta"] {
        let est = estimator_by_name(name).unwrap();
        assert!(est.scores_packed_manhattan(), "{name}");
        for t in &tiles {
            assert_eq!(
                est.nf_sum(t, &physics).unwrap().to_bits(),
                Analytic.nf_sum(t, &physics).unwrap().to_bits(),
                "{name} nf_sum"
            );
            assert_eq!(
                est.nf_mean(t, &physics).unwrap().to_bits(),
                Analytic.nf_mean(t, &physics).unwrap().to_bits(),
                "{name} nf_mean"
            );
            let a = est.nf_per_col(t, &physics).unwrap();
            let b = Analytic.nf_per_col(t, &physics).unwrap();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{name} nf_per_col");
            }
        }
    }
}

/// Property: packed row/column permutations commute with packing — the
/// permuted bitmasks equal the packed permuted tensor, so plan application
/// on bitmasks (the pipeline fast path) can never drift from the tensors.
#[test]
fn packed_permutes_match_tensor_permutes() {
    propcheck(
        PropConfig { cases: 64, seed: 0xB17_0003, max_size: 40 },
        |rng, size| {
            let rows = 1 + rng.below(size as u64) as usize;
            let cols = 1 + rng.below((96 + size) as u64) as usize;
            let t = random_planes(rows, cols, rng.uniform_range(0.05, 0.6), rng);
            let rp = rng.permutation(rows);
            let cp = rng.permutation(cols);
            (t, rp, cp)
        },
        |(t, rp, cp)| {
            let via_tensor = PackedPlanes::from_tensor(
                &t.permute_rows(rp)
                    .map_err(|e| e.to_string())?
                    .permute_cols(cp)
                    .map_err(|e| e.to_string())?,
            )
            .map_err(|e| e.to_string())?;
            let via_packed = PackedPlanes::from_tensor(t)
                .map_err(|e| e.to_string())?
                .permute_rows(rp)
                .map_err(|e| e.to_string())?
                .permute_cols(cp)
                .map_err(|e| e.to_string())?;
            if via_packed != via_tensor {
                return Err("permuted bitmasks diverged from packed permuted tensor".into());
            }
            Ok(())
        },
    );
}

/// The `testsupport` bit-plane generator honours its density profile: with
/// a low-order-dense profile, higher-order planes (lower plane index — bit
/// 0 is the highest order in this repo's slicing) are strictly sparser in
/// expectation, and the kernels stay bitwise exact on its output.
#[test]
fn generated_bit_sliced_tiles_are_low_order_dense_and_score_exactly() {
    let k = 8;
    let densities = low_order_dense_densities(k, 0.5, 0.5);
    for b in 1..k {
        assert!(densities[b] > densities[b - 1], "profile must decay toward the MSB");
    }
    let mut rng = Xoshiro256::seeded(0xB17_0004);
    let t = random_bit_sliced_planes(&mut rng, 96, 64, &densities);
    assert_eq!(t.shape(), &[96, 64 * k]);
    check_bitwise(&t, 2.5 / 300e3).unwrap();
    // Empirical per-plane activity: the MSB plane (bit 0) must be much
    // sparser than the LSB plane (bit k-1).
    let plane_count = |b: usize| -> usize {
        let mut n = 0;
        for j in 0..t.rows() {
            for c in (b..t.cols()).step_by(k) {
                if t.at2(j, c) != 0.0 {
                    n += 1;
                }
            }
        }
        n
    };
    let msb = plane_count(0);
    let lsb = plane_count(k - 1);
    assert!(
        (msb as f64) < 0.25 * lsb as f64,
        "MSB plane ({msb} active) should be far sparser than LSB ({lsb})"
    );
}
