//! Integration tests over the pure-Rust pipeline: quantization → tiling →
//! MDM mapping → NF / distortion, plus property tests via
//! `testsupport::propcheck`. No artifacts required.

use mdm_cim::circuit::CrossbarCircuit;
use mdm_cim::crossbar::{LayerTiling, TileGeometry};
use mdm_cim::eval::random_planes;
use mdm_cim::mdm::{plan_tile, strategy_by_name, Identity, MagnitudeDesc, Mdm, SlicedTile};
use mdm_cim::models::{generate_layer_weights, WeightProfile};
use mdm_cim::nf::{manhattan_nf_mean, manhattan_nf_sum};
use mdm_cim::quant::{BitSlicedMatrix, SignSplit};
use mdm_cim::rng::Xoshiro256;
use mdm_cim::tensor::Tensor;
use mdm_cim::testsupport::{propcheck, PropConfig};
use mdm_cim::CrossbarPhysics;

/// Full pipeline on a realistic layer: every stage composes and MDM ends up
/// with a lower NF and a smaller accuracy-relevant distortion.
#[test]
fn full_mapping_pipeline() {
    let w = generate_layer_weights(256, 32, &WeightProfile::cnn(), 11).unwrap();
    let split = SignSplit::of(&w);
    let geom = TileGeometry::paper_eval();
    let conv_s = strategy_by_name("conventional").unwrap();
    let mdm_s = strategy_by_name("mdm").unwrap();
    for part in [&split.pos, &split.neg] {
        let tiling = LayerTiling::partition(part, geom).unwrap();
        let mut nf_conv = 0.0;
        let mut nf_mdm = 0.0;
        for tile in &tiling.tiles {
            let conv = tile.plan(conv_s.as_ref());
            let mdm = tile.plan(mdm_s.as_ref());
            nf_conv += manhattan_nf_mean(&conv.apply(&tile.sliced.planes).unwrap(), 1.0);
            nf_mdm += manhattan_nf_mean(&mdm.apply(&tile.sliced.planes).unwrap(), 1.0);
        }
        assert!(nf_mdm < nf_conv, "MDM {nf_mdm} !< conventional {nf_conv}");
    }
}

/// Property: the MDM row sort never increases the Manhattan NF at a fixed
/// dataflow, for arbitrary random tiles of any size/density. (The dataflow
/// *reversal* is only guaranteed to help for Theorem-1 tiles whose
/// low-order columns are denser; uniform-random tiles have no gradient, so
/// the invariant is stated per-dataflow — see mdm::tests for the
/// gradient case.)
#[test]
fn prop_row_sort_never_worse_per_dataflow() {
    use mdm_cim::mdm::Dataflow;
    propcheck(
        PropConfig { cases: 48, seed: 101, max_size: 48 },
        |rng, size| {
            let rows = 2 + rng.below(size as u64 + 2) as usize;
            let cols = 2 + rng.below(size as u64 + 2) as usize;
            let density = rng.uniform_range(0.05, 0.6);
            random_planes(rows, cols, density, rng)
        },
        |planes| {
            let tile = SlicedTile::from_planes(planes.clone()).map_err(|e| e.to_string())?;
            for dataflow in [Dataflow::Conventional, Dataflow::Reversed] {
                let ident = plan_tile(&Identity { dataflow }, &tile);
                let sorted = plan_tile(&Mdm { dataflow }, &tile);
                let a = manhattan_nf_sum(&ident.apply(planes).unwrap(), 1.0);
                let b = manhattan_nf_sum(&sorted.apply(planes).unwrap(), 1.0);
                if b > a + 1e-9 {
                    return Err(format!("sorted NF {b} > identity {a} at {dataflow:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Property: the mapping plan preserves arithmetic exactly (row perm on
/// activations + col un-perm on outputs reproduces x @ W).
#[test]
fn prop_mapping_preserves_product() {
    propcheck(
        PropConfig { cases: 32, seed: 202, max_size: 24 },
        |rng, size| {
            let j = 2 + size;
            let n = 1 + size / 3;
            let wdata: Vec<f32> =
                (0..j * n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
            let xdata: Vec<f32> =
                (0..2 * j).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
            (
                Tensor::new(&[j, n], wdata).unwrap(),
                Tensor::new(&[2, j], xdata).unwrap(),
                rng.permutation(j),
                rng.permutation(n),
            )
        },
        |(w, x, rp, cp)| {
            let plan = mdm_cim::mdm::MappingPlan::new(rp.clone(), cp.clone());
            let y_ref = x.matmul(w).unwrap();
            let y = plan
                .unapply_to_outputs(
                    &plan
                        .apply_to_activations(x)
                        .unwrap()
                        .matmul(&plan.apply(w).unwrap())
                        .unwrap(),
                )
                .unwrap();
            let err = y_ref
                .data()
                .iter()
                .zip(y.data())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("product changed by {err}"))
            }
        },
    );
}

/// Property: quantize→slice→dequantize error stays within one LSB for any
/// non-negative matrix.
#[test]
fn prop_quantization_error_bounded() {
    propcheck(
        PropConfig { cases: 40, seed: 303, max_size: 32 },
        |rng, size| {
            let j = 1 + size;
            let n = 1 + size / 4;
            let data: Vec<f32> = (0..j * n).map(|_| rng.laplace(0.3).abs() as f32).collect();
            Tensor::new(&[j, n], data).unwrap()
        },
        |w| {
            let s = BitSlicedMatrix::slice(w, 8).map_err(|e| e.to_string())?;
            let d = s.dequantize().map_err(|e| e.to_string())?;
            let tol = s.quant.max_abs_error() + 1e-6;
            for (a, b) in w.data().iter().zip(d.data()) {
                if (a - b).abs() > tol {
                    return Err(format!("{a} vs {b} (tol {tol})"));
                }
            }
            Ok(())
        },
    );
}

/// Property: circuit-solver NF is anti-diagonally symmetric for any single
/// active cell on square crossbars.
#[test]
fn prop_circuit_antidiagonal_symmetry() {
    let physics = CrossbarPhysics { r_off: f64::INFINITY, ..CrossbarPhysics::default() };
    propcheck(
        PropConfig { cases: 12, seed: 404, max_size: 10 },
        |rng, size| {
            let n = 2 + size.min(10);
            let j = rng.below(n as u64) as usize;
            let k = rng.below(n as u64) as usize;
            (n, j, k)
        },
        |&(n, j, k)| {
            let mut a = CrossbarCircuit::new(n, n, physics).map_err(|e| e.to_string())?;
            a.set_active(j, k, true);
            let mut b = CrossbarCircuit::new(n, n, physics).map_err(|e| e.to_string())?;
            b.set_active(k, j, true);
            let nfa = a.solve().map_err(|e| e.to_string())?.nf();
            let nfb = b.solve().map_err(|e| e.to_string())?.nf();
            if (nfa - nfb).abs() <= 1e-9 + nfa.abs() * 1e-6 {
                Ok(())
            } else {
                Err(format!("NF({j},{k})={nfa} vs NF({k},{j})={nfb}"))
            }
        },
    );
}

/// Property: the *significance-weighted* row sort (`MagnitudeDesc`, i.e.
/// rows ordered by dequantized magnitude mass) never increases the Eq.-17
/// weight-space distortion at a fixed dataflow. This is the exact
/// rearrangement-optimal order for weight-space error — the cell-count
/// MDM score is optimal for the *current-domain* NF instead; the two
/// objectives differ, which is the decomposition analyzed in
/// rust/DESIGN.md "beyond the paper".
#[test]
fn prop_magnitude_sort_distortion_never_worse() {
    use mdm_cim::mdm::Dataflow;
    propcheck(
        PropConfig { cases: 24, seed: 505, max_size: 24 },
        |rng, size| {
            let j = 8 + size;
            let n = 2 + size / 6;
            let data: Vec<f32> =
                (0..j * n).map(|_| rng.laplace(0.15).abs() as f32).collect();
            Tensor::new(&[j, n], data).unwrap()
        },
        |w| {
            let s = BitSlicedMatrix::slice(w, 8).map_err(|e| e.to_string())?;
            let conv = plan_tile(&Identity::conventional(), &s);
            let sorted =
                plan_tile(&MagnitudeDesc { dataflow: Dataflow::Conventional }, &s);
            let dc = mdm_cim::noise::mean_relative_distortion(&s, &conv, -2e-3)
                .map_err(|e| e.to_string())?;
            let dm = mdm_cim::noise::mean_relative_distortion(&s, &sorted, -2e-3)
                .map_err(|e| e.to_string())?;
            if dm <= dc + 1e-9 {
                Ok(())
            } else {
                Err(format!("magnitude-sorted distortion {dm} > conventional {dc}"))
            }
        },
    );
}

/// The circuit solver and the Manhattan model agree on *ranking*: if the
/// model says MDM reduced the aggregate distance, the solver must see a
/// lower measured NF too (checked on bell-shaped tiles).
#[test]
fn solver_confirms_mdm_nf_reduction() {
    let mut rng = Xoshiro256::seeded(77);
    let physics = CrossbarPhysics::default();
    let mut better = 0usize;
    let n_tiles = 6;
    for t in 0..n_tiles {
        // Bell-shaped bit-sliced tile: low-order columns denser.
        let w = generate_layer_weights(32, 4, &WeightProfile::cnn(), 1000 + t as u64).unwrap();
        let split = SignSplit::of(&w);
        let s = BitSlicedMatrix::slice(&split.pos, 8).unwrap();
        let conv = plan_tile(&Identity::conventional(), &s);
        let mdm = plan_tile(&Mdm::reversed(), &s);
        let nf_conv = CrossbarCircuit::from_planes(&conv.apply(&s.planes).unwrap(), physics)
            .unwrap()
            .solve()
            .unwrap()
            .nf();
        let nf_mdm = CrossbarCircuit::from_planes(&mdm.apply(&s.planes).unwrap(), physics)
            .unwrap()
            .solve()
            .unwrap()
            .nf();
        if nf_mdm < nf_conv {
            better += 1;
        }
        let _ = rng.next_u64();
    }
    assert!(
        better >= n_tiles - 1,
        "solver confirmed MDM on only {better}/{n_tiles} tiles"
    );
}
