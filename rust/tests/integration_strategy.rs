//! Property tests for [`MappingPlan`] invariants across **every registered
//! strategy**: whatever placement a strategy picks, (a) its permutations
//! round-trip the planes bitwise, (b) activation-permute + output-un-permute
//! reproduces the unmapped matvec (to f32 accumulation-order tolerance —
//! a row permutation reorders the dot-product reduction, so exact bitwise
//! equality only holds for column-only plans), and (c) degenerate tiles
//! (1 row, 1 column, 1x1, all-zero planes) never panic.

use mdm_cim::mdm::{plan_tile, strategy_by_name, strategy_names, MappingStrategy, SlicedTile};
use mdm_cim::quant::BitSlicedMatrix;
use mdm_cim::rng::Xoshiro256;
use mdm_cim::tensor::Tensor;
use std::sync::Arc;

fn all_strategies() -> Vec<(&'static str, Arc<dyn MappingStrategy>)> {
    strategy_names()
        .iter()
        .map(|(name, _)| (*name, strategy_by_name(name).expect("registered name resolves")))
        .collect()
}

fn random_planes(rows: usize, cols: usize, density: f64, rng: &mut Xoshiro256) -> Tensor {
    let data: Vec<f32> =
        (0..rows * cols).map(|_| if rng.bernoulli(density) { 1.0 } else { 0.0 }).collect();
    Tensor::new(&[rows, cols], data).unwrap()
}

/// A real bit-sliced tile from a bell-shaped weight matrix.
fn bell_tile(rows: usize, weights: usize, seed: u64) -> BitSlicedMatrix {
    let mut rng = Xoshiro256::seeded(seed);
    let data: Vec<f32> =
        (0..rows * weights).map(|_| rng.laplace(0.2).abs() as f32).collect();
    let w = Tensor::new(&[rows, weights], data).unwrap();
    BitSlicedMatrix::slice(&w, 8).unwrap()
}

/// (a) `unapply(apply(planes)) == planes` **bitwise**, for every strategy
/// and a spread of tile shapes — the pure-permutation round-trip.
#[test]
fn planes_roundtrip_bitwise_for_every_strategy() {
    let mut rng = Xoshiro256::seeded(11);
    for (rows, cols) in [(4usize, 4usize), (16, 8), (7, 13), (32, 32)] {
        let planes = random_planes(rows, cols, 0.3, &mut rng);
        let tile = SlicedTile::from_planes(planes.clone()).unwrap();
        for (name, strategy) in all_strategies() {
            let plan = plan_tile(strategy.as_ref(), &tile);
            let phys = plan.apply(&planes).unwrap();
            assert_eq!(
                plan.unapply(&phys).unwrap(),
                planes,
                "{name} round-trip not bitwise on {rows}x{cols}"
            );
        }
    }
}

/// (b) The mapped matvec is the unmapped matvec: permute activations in,
/// multiply by the physically laid-out planes, un-permute outputs.
#[test]
fn matvec_preserved_for_every_strategy() {
    let mut rng = Xoshiro256::seeded(22);
    for seed in 0..4u64 {
        let sliced = bell_tile(24, 3, 100 + seed);
        let xdata: Vec<f32> =
            (0..2 * sliced.rows()).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let x = Tensor::new(&[2, sliced.rows()], xdata).unwrap();
        let y_ref = x.matmul(&sliced.planes).unwrap();
        for (name, strategy) in all_strategies() {
            let plan = plan_tile(strategy.as_ref(), &sliced);
            let y = plan
                .unapply_to_outputs(
                    &plan
                        .apply_to_activations(&x)
                        .unwrap()
                        .matmul(&plan.apply(&sliced.planes).unwrap())
                        .unwrap(),
                )
                .unwrap();
            let err = y_ref
                .data()
                .iter()
                .zip(y.data())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 1e-4, "{name} changed the product by {err}");
        }
    }
}

/// (c) Degenerate tiles must not panic under any registered strategy, and
/// their plans must still be valid permutations.
#[test]
fn degenerate_tiles_do_not_panic() {
    let single_row = random_planes(1, 8, 0.5, &mut Xoshiro256::seeded(1));
    let single_col = random_planes(8, 1, 0.5, &mut Xoshiro256::seeded(2));
    let unit = random_planes(1, 1, 1.0, &mut Xoshiro256::seeded(3));
    let all_zero = Tensor::zeros(&[6, 6]);
    for planes in [&single_row, &single_col, &unit, &all_zero] {
        let tile = SlicedTile::from_planes(planes.clone()).unwrap();
        for (name, strategy) in all_strategies() {
            let plan = plan_tile(strategy.as_ref(), &tile);
            assert_eq!(plan.rows(), planes.rows(), "{name}");
            assert_eq!(plan.cols(), planes.cols(), "{name}");
            // apply must succeed and round-trip.
            let phys = plan.apply(planes).unwrap();
            assert_eq!(plan.unapply(&phys).unwrap(), *planes, "{name}");
        }
    }
    // An all-zero *weight* tile (real quantizer path) must also plan fine.
    let zero_w = Tensor::zeros(&[8, 2]);
    let sliced = BitSlicedMatrix::slice(&zero_w, 8).unwrap();
    for (name, strategy) in all_strategies() {
        let plan = plan_tile(strategy.as_ref(), &sliced);
        assert_eq!(plan.rows(), 8, "{name}");
    }
}

/// The plan's logical distance matrix is consistent with its permutations
/// for every strategy (the tensor the L1 kernel consumes).
#[test]
fn logical_distances_consistent_for_every_strategy() {
    let sliced = bell_tile(16, 2, 7);
    for (name, strategy) in all_strategies() {
        let plan = plan_tile(strategy.as_ref(), &sliced);
        let d = plan.logical_distance_matrix();
        for l_row in 0..plan.rows() {
            for l_col in 0..plan.cols() {
                assert_eq!(
                    d.at2(l_row, l_col) as usize,
                    plan.logical_cell_distance(l_row, l_col),
                    "{name} at ({l_row},{l_col})"
                );
            }
        }
    }
}
