//! Integration tests of the anytime annealing placer and the `DeltaCost`
//! incremental re-scorer: bitwise-identical results at any worker-thread
//! count, exact degradation to the `nf_aware` seed at zero budget,
//! `DeltaCost` pinned against full `Scheduler` re-scoring over random move
//! traces under both spill policies, and context-rich errors (no panics)
//! on degenerate workloads. No artifacts are required.

use mdm_cim::chip::{
    placer_by_name, placer_names, Annealer, ChipModel, ChipWorkload, DeltaCost, PlacedBlock,
    Placer, Scheduler, SpillPolicy,
};
use mdm_cim::crossbar::{CostModel, TileGeometry};
use mdm_cim::parallel::{install_global, ParallelConfig};
use mdm_cim::rng::Xoshiro256;

/// A three-layer ragged workload that overflows one 8x8 chip (96 slots on
/// 64), so every placement exercises spill regions.
fn workload(chip: ChipModel) -> ChipWorkload {
    let mut wl = ChipWorkload::new(chip).unwrap();
    wl.add_layer("stem", 0, 96, 24, 2.0).unwrap(); // 6x6 grid per part
    wl.add_layer("mid", 1, 48, 12, 1.5).unwrap(); // 3x3 grid per part
    wl.add_layer("head", 2, 48, 4, 0.5).unwrap(); // 3x1 grid per part
    wl
}

fn chip_8x8(spill: SpillPolicy) -> ChipModel {
    ChipModel {
        slot_rows: 8,
        slot_cols: 8,
        geometry: TileGeometry::new(16, 32, 8).unwrap(),
        spill,
        ..ChipModel::default()
    }
}

/// The annealer's chains are seed-split and its reduction is ordered, so
/// the best placement must be bitwise identical at 1, 2, 4, and 8 worker
/// threads.
#[test]
fn annealed_placement_bitwise_identical_across_thread_counts() {
    let wl = workload(chip_8x8(SpillPolicy::MoreChips));
    let annealer = Annealer { budget_ms: 3 };
    let prior = ParallelConfig::default().threads;
    let key = |p: &mdm_cim::chip::Placement| -> Vec<(usize, usize, usize, usize)> {
        p.placed.iter().map(|q| (q.block, q.region, q.row, q.col)).collect()
    };
    let mut results: Vec<Vec<(usize, usize, usize, usize)>> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        install_global(threads);
        let placed = annealer.place(&wl);
        install_global(prior);
        let placement = placed.unwrap();
        placement.validate().unwrap();
        results.push(key(&placement));
    }
    for (i, r) in results.iter().enumerate().skip(1) {
        assert_eq!(r, &results[0], "thread count #{i} diverged from serial");
    }
}

/// `anneal:0` (and an empty budget) must return the `nf_aware` seed
/// placement verbatim, rebadged under the `anneal` registry name.
#[test]
fn zero_budget_anneal_degrades_to_the_nf_aware_seed() {
    let wl = workload(chip_8x8(SpillPolicy::MoreChips));
    let seed = placer_by_name("nf_aware").unwrap().place(&wl).unwrap();
    let zero = placer_by_name("anneal:0").unwrap().place(&wl).unwrap();
    assert_eq!(zero.placer, "anneal");
    assert_eq!(zero.regions, seed.regions);
    assert_eq!(zero.placed.len(), seed.placed.len());
    for (a, b) in zero.placed.iter().zip(&seed.placed) {
        assert_eq!(
            (a.block, a.region, a.row, a.col),
            (b.block, b.region, b.row, b.col),
            "zero-budget anneal must not move any fragment"
        );
    }
}

/// Replay a random trace of same-shape swaps and free-spot relocations,
/// asserting after every move that `DeltaCost::score` is bitwise identical
/// to a full `Scheduler::schedule` pass plus NF rescan on the mirrored
/// placement.
fn pin_delta_cost_against_full_rescoring(spill: SpillPolicy, batch: usize, steps: usize) {
    let chip = chip_8x8(spill);
    let wl = workload(chip);
    let seed = placer_by_name("nf_aware").unwrap().place(&wl).unwrap();
    let cost = CostModel::default();
    let scheduler = Scheduler { cost };
    let mut dc = DeltaCost::new(&seed, cost, batch).unwrap();
    let mut full = seed.clone();
    let (rows, cols) = (chip.slot_rows, chip.slot_cols);

    // Local occupancy mirror so relocations only target free rectangles.
    let mut occ = vec![vec![false; rows * cols]; full.regions];
    for p in &full.placed {
        let b = &full.blocks[p.block];
        for r in p.row..p.row + b.rows {
            for c in p.col..p.col + b.cols {
                occ[p.region][r * cols + c] = true;
            }
        }
    }
    let free = |occ: &[Vec<bool>], g: usize, r: usize, c: usize, h: usize, w: usize| {
        (r..r + h).all(|i| (c..c + w).all(|j| !occ[g][i * cols + j]))
    };
    let set = |occ: &mut [Vec<bool>], p: &PlacedBlock, h: usize, w: usize, v: bool| {
        for i in p.row..p.row + h {
            for j in p.col..p.col + w {
                occ[p.region][i * cols + j] = v;
            }
        }
    };

    // Same-shape swap partners, fixed for the whole trace.
    let mut buckets: std::collections::BTreeMap<(usize, usize), Vec<usize>> = Default::default();
    for (i, p) in full.placed.iter().enumerate() {
        let b = &full.blocks[p.block];
        buckets.entry((b.rows, b.cols)).or_default().push(i);
    }
    let swappable: Vec<Vec<usize>> = buckets.into_values().filter(|v| v.len() >= 2).collect();
    assert!(!swappable.is_empty(), "trace workload needs a same-shape pair");

    let mut rng = Xoshiro256::seeded(0xBEEF ^ batch as u64);
    let mut relocated = 0usize;
    for step in 0..steps {
        if rng.below(2) == 0 {
            let bucket = &swappable[rng.below(swappable.len() as u64) as usize];
            let ai = rng.below(bucket.len() as u64) as usize;
            let mut bi = rng.below(bucket.len() as u64 - 1) as usize;
            if bi >= ai {
                bi += 1;
            }
            let (a, b) = (bucket[ai], bucket[bi]);
            dc.swap(a, b).unwrap();
            let (pa, pb) = (full.placed[a], full.placed[b]);
            full.placed[a] = PlacedBlock { block: pa.block, ..pb };
            full.placed[b] = PlacedBlock { block: pb.block, ..pa };
            // Occupancy is unchanged: two equal-shape rectangles traded.
        } else {
            let pi = rng.below(full.placed.len() as u64) as usize;
            let p = full.placed[pi];
            let b = &full.blocks[p.block];
            let (h, w) = (b.rows, b.cols);
            set(&mut occ, &p, h, w, false);
            let mut dest = None;
            for _ in 0..20 {
                let g = rng.below(full.regions as u64) as usize;
                let r = rng.below((rows - h + 1) as u64) as usize;
                let c = rng.below((cols - w + 1) as u64) as usize;
                if free(&occ, g, r, c, h, w) {
                    dest = Some((g, r, c));
                    break;
                }
            }
            match dest {
                Some((g, r, c)) => {
                    dc.relocate(pi, g, r, c).unwrap();
                    full.placed[pi] = PlacedBlock { block: p.block, region: g, row: r, col: c };
                    set(&mut occ, &full.placed[pi], h, w, true);
                    relocated += 1;
                }
                None => set(&mut occ, &p, h, w, true),
            }
        }
        let ds = dc.score();
        let report = scheduler.schedule(&full, batch).unwrap();
        assert_eq!(
            ds.nf_weighted_cost.to_bits(),
            full.nf_weighted_cost().to_bits(),
            "NF diverged at step {step} ({spill:?})"
        );
        assert_eq!(
            ds.latency_ns.to_bits(),
            report.total.latency_ns.to_bits(),
            "latency diverged at step {step} ({spill:?})"
        );
        assert_eq!(
            ds.energy_pj.to_bits(),
            report.total.energy_pj.to_bits(),
            "energy diverged at step {step} ({spill:?})"
        );
    }
    assert!(relocated > 0, "the trace never exercised a relocation");
}

/// `DeltaCost` vs full re-scoring under parallel spill (one region per
/// chip).
#[test]
fn delta_cost_pinned_against_full_rescoring_more_chips() {
    pin_delta_cost_against_full_rescoring(SpillPolicy::MoreChips, 3, 160);
}

/// `DeltaCost` vs full re-scoring under reuse spill, where round switches
/// pay reprogramming cost and moves can change the round structure.
#[test]
fn delta_cost_pinned_against_full_rescoring_reuse() {
    pin_delta_cost_against_full_rescoring(SpillPolicy::Reuse, 2, 160);
}

/// Degenerate workloads come back as context-rich errors, not panics:
/// zero-tile layers are rejected at construction, batch 0 is rejected by
/// both the scheduler and the re-scorer.
#[test]
fn degenerate_inputs_error_with_context_instead_of_panicking() {
    let mut wl = ChipWorkload::new(ChipModel::default()).unwrap();
    assert!(wl.add_layer("z", 0, 0, 4, 1.0).is_err(), "zero fan-in must be rejected");
    assert!(wl.add_layer("z", 0, 16, 0, 1.0).is_err(), "zero fan-out must be rejected");
    wl.add_layer("ok", 0, 16, 4, 1.0).unwrap();
    let placement = placer_by_name("firstfit").unwrap().place(&wl).unwrap();
    let err = Scheduler::default().schedule(&placement, 0).unwrap_err();
    assert!(err.to_string().contains("batch"), "{err:#}");
    assert!(DeltaCost::new(&placement, CostModel::default(), 0).is_err());
}

/// A 1x1-slot chip is a legal degenerate target: every registered placer
/// places a two-fragment workload onto it (one region per fragment) and the
/// schedule prices it end to end.
#[test]
fn single_slot_chip_places_and_schedules_end_to_end() {
    let chip = ChipModel {
        slot_rows: 1,
        slot_cols: 1,
        geometry: TileGeometry::new(16, 32, 8).unwrap(),
        ..ChipModel::default()
    };
    let mut wl = ChipWorkload::new(chip).unwrap();
    wl.add_layer("tiny", 0, 16, 4, 1.0).unwrap(); // 1x1 grid per part
    for (name, _) in placer_names() {
        let placer = placer_by_name(name).unwrap();
        let placement = placer.place(&wl).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        placement.validate().unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(placement.regions, 2, "{name}: one slot per region");
        let report = Scheduler::default().schedule(&placement, 2).unwrap();
        assert!(report.total.latency_ns > 0.0, "{name}");
    }
}
