//! Determinism contract of the parallel evaluation engine: every parallel
//! path must produce **bitwise identical** results to its serial
//! counterpart at any thread count (the same contract `mdm bench` enforces
//! before emitting `BENCH_parallel_nf.json`).

use mdm_cim::circuit::{measure_tile_nfs, single_cell_nf_map_with};
use mdm_cim::crossbar::TileGeometry;
use mdm_cim::eval::random_planes;
use mdm_cim::nf::manhattan_nf_sum_batch;
use mdm_cim::parallel::ParallelConfig;
use mdm_cim::pipeline::Pipeline;
use mdm_cim::rng::Xoshiro256;
use mdm_cim::tensor::Tensor;
use mdm_cim::CrossbarPhysics;

fn random_tiles(n: usize, side: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..n).map(|_| random_planes(side, side, 0.2, &mut rng)).collect()
}

/// Measured (circuit-solved) NF of a tile population: parallel == serial,
/// bit for bit, across several thread counts.
#[test]
fn measured_nf_bitwise_identical_across_thread_counts() {
    let tiles = random_tiles(10, 16, 1);
    let physics = CrossbarPhysics::default();
    let reference = measure_tile_nfs(&tiles, physics, &ParallelConfig::serial()).unwrap();
    for threads in [2usize, 3, 4, 8] {
        let par =
            measure_tile_nfs(&tiles, physics, &ParallelConfig::with_threads(threads)).unwrap();
        assert_eq!(par.len(), reference.len());
        for (i, (a, b)) in reference.iter().zip(&par).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "tile {i} diverged at {threads} threads");
        }
    }
}

/// Analytical (Eq. 16) NF batch: same contract.
#[test]
fn analytical_nf_bitwise_identical_across_thread_counts() {
    let tiles = random_tiles(17, 32, 2);
    let ratio = CrossbarPhysics::default().parasitic_ratio();
    let reference = manhattan_nf_sum_batch(&tiles, ratio, &ParallelConfig::serial());
    for threads in [2usize, 5, 16] {
        let par = manhattan_nf_sum_batch(&tiles, ratio, &ParallelConfig::with_threads(threads));
        for (a, b) in reference.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

/// The Fig. 2 single-cell sweep (Sherman–Morrison toggles off one shared
/// factorization): parallel == serial.
#[test]
fn single_cell_map_bitwise_identical() {
    let physics = CrossbarPhysics { r_off: f64::INFINITY, ..CrossbarPhysics::default() };
    let serial = single_cell_nf_map_with(9, 7, physics, &ParallelConfig::serial()).unwrap();
    let par = single_cell_nf_map_with(9, 7, physics, &ParallelConfig::with_threads(4)).unwrap();
    assert_eq!(serial.shape(), par.shape());
    for (a, b) in serial.data().iter().zip(par.data()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// Whole-pipeline programming (plan + Eq.-17 distortion per tile): the
/// effective weight matrix is bitwise identical however many workers
/// programmed it.
#[test]
fn programmed_layer_bitwise_identical() {
    let mut rng = Xoshiro256::seeded(3);
    let data: Vec<f32> = (0..128 * 16).map(|_| rng.laplace(0.2) as f32).collect();
    let w = Tensor::new(&[128, 16], data).unwrap();
    let g = TileGeometry::new(32, 32, 8).unwrap();
    let compile = |threads: usize| {
        Pipeline::new(g)
            .strategy("mdm")
            .unwrap()
            .eta_signed(-2e-3)
            .parallel(ParallelConfig::with_threads(threads))
            .compile(&w)
            .unwrap()
    };
    let reference = compile(1);
    let ref_data = reference.effective_weights().data();
    for threads in [2usize, 4] {
        let par = compile(threads);
        for (a, b) in ref_data.iter().zip(par.effective_weights().data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads diverged");
        }
    }
}
