//! Golden-number regression gates: the headline quantities of each
//! reproduced figure, pinned with tolerances wide enough for seed/platform
//! drift but tight enough to catch real regressions in the solver, the
//! mapping, or the NF model. (Small problem sizes keep this under a few
//! seconds; the full-scale numbers live in rust/DESIGN.md.)

use mdm_cim::eval;
use mdm_cim::CrossbarPhysics;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("golden_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Fig. 2: NF-vs-distance slope equals r/R_on within 2%, r² ≈ 1, exact
/// anti-diagonal symmetry.
#[test]
fn golden_fig2() {
    let dir = tmp("fig2");
    let r = eval::fig2::run(16, CrossbarPhysics::default(), &dir).unwrap();
    assert!(r.max_asymmetry < 1e-9, "asymmetry {}", r.max_asymmetry);
    let rel = (r.linear_fit.slope - r.theory_slope).abs() / r.theory_slope;
    assert!(rel < 0.02, "slope off by {:.3}%", 100.0 * rel);
    assert!(r.linear_fit.r2 > 0.9999);
    std::fs::remove_dir_all(&dir).ok();
}

/// Fig. 4: Eq.-16 sum form explains the measured NF (r² > 0.98) with a
/// near-zero mean error.
#[test]
fn golden_fig4() {
    let dir = tmp("fig4");
    let cfg = eval::fig4::Fig4Config { n_tiles: 60, tile: 32, ..Default::default() };
    let r = eval::fig4::run(cfg, &dir).unwrap();
    // (0.98+ at the full 500×64×64 scale; the quick 60×32×32 gate allows a
    // little more sampling noise.)
    assert!(r.fit.fit.r2 > 0.95, "r2 {}", r.fit.fit.r2);
    assert!(r.fit.error_summary.mean.abs() < 1.0, "mu {}", r.fit.error_summary.mean);
    assert!(r.fit.error_summary.std < 5.0, "sigma {}", r.fit.error_summary.std);
    std::fs::remove_dir_all(&dir).ok();
}

/// Fig. 5 shape: MDM reduces NF on every model; CNN family beats the
/// transformer family; full reduction lands in the 10–25% band at 64×64.
#[test]
fn golden_fig5_shape() {
    let dir = tmp("fig5");
    let cfg = eval::fig5::Fig5Config {
        models: vec!["resnet18".into(), "deit_s".into()],
        tiles_per_layer: 6,
        ..Default::default()
    };
    let rows = eval::fig5::run(&cfg, &dir).unwrap();
    for r in &rows {
        assert!(r.reduction_full() > 10.0 && r.reduction_full() < 25.0, "{r:?}");
    }
    assert!(rows[0].reduction_full() > rows[1].reduction_full());
    std::fs::remove_dir_all(&dir).ok();
}

/// A1 trend: at 16×16 the MDM reduction exceeds 30% (the path to the
/// paper's "up to 46%") and sync costs fall as tiles grow.
#[test]
fn golden_tilesize_trend() {
    let dir = tmp("ts");
    let rows = eval::ablations::tile_size_sweep(&[16, 64], 8, 42, &dir).unwrap();
    let red16 = 100.0 * (1.0 - rows[0].nf_mdm / rows[0].nf_conventional);
    assert!(red16 > 30.0, "16x16 reduction {red16}%");
    assert!(rows[0].sync_events > rows[1].sync_events);
    std::fs::remove_dir_all(&dir).ok();
}

/// E6: η calibrates to within [1x, 100x] of r/R_on on the linear mesh and
/// the two estimators agree.
#[test]
fn golden_eta_calibration() {
    let dir = tmp("eta");
    let c = eval::calibrate::run(30, 32, 0.8, CrossbarPhysics::default(), 42, &dir).unwrap();
    let ratio = c.eta_mean / CrossbarPhysics::default().parasitic_ratio();
    assert!((1.0..100.0).contains(&ratio), "eta/r_ratio = {ratio}");
    assert!((c.eta_ols / c.eta_mean - 1.0).abs() < 0.5);
    std::fs::remove_dir_all(&dir).ok();
}
