//! `cargo bench` — micro-benchmarks of the L3 hot paths, used by the
//! rust/DESIGN.md §6 (Perf) iteration loop.
//!
//!   solver:   banded Cholesky factor+solve, CG, Sherman–Morrison toggles
//!   mapping:  bit-slicing, row scoring, plan application
//!   noise:    Eq.-17 effective-weight computation
//!   tensor:   the blocked matmul under the tiled fallback path
//!   runtime:  PJRT kernel dispatch (needs artifacts)
//!   serving:  engine inference end-to-end (needs artifacts)

use mdm_cim::circuit::CrossbarCircuit;
use mdm_cim::coordinator::{Engine, EngineConfig, ModelKind};
use mdm_cim::crossbar::TileGeometry;
use mdm_cim::eval::random_planes;
use mdm_cim::mdm::{plan_tile, strategy_by_name};
use mdm_cim::noise::distorted_weights;
use mdm_cim::quant::BitSlicedMatrix;
use mdm_cim::report::write_csv;
use mdm_cim::rng::Xoshiro256;
use mdm_cim::runtime::ArtifactStore;
use mdm_cim::tensor::Tensor;
use mdm_cim::testsupport::bench;
use mdm_cim::CrossbarPhysics;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let out = Path::new("results/bench");
    std::fs::create_dir_all(out)?;
    let mut timing: Vec<Vec<String>> = Vec::new();
    let mut record = |name: &str, s: mdm_cim::testsupport::BenchStats| {
        timing.push(vec![
            name.to_string(),
            format!("{:.6}", s.mean_s),
            format!("{:.6}", s.std_s),
            format!("{:.6}", s.min_s),
        ]);
    };
    let physics = CrossbarPhysics::default();
    let mut rng = Xoshiro256::seeded(1);

    println!("== circuit solver =========================================================");
    let planes64 = random_planes(64, 64, 0.2, &mut rng);
    let c64 = CrossbarCircuit::from_planes(&planes64, physics)?;
    let s = bench("solve_cholesky_64x64", 1, 5, || {
        c64.solve().unwrap();
    });
    record("solve_cholesky_64x64", s);
    let s = bench("solve_cg_64x64", 1, 3, || {
        c64.solve_cg(1e-10).unwrap();
    });
    record("solve_cg_64x64", s);
    let solver = c64.factorize()?;
    let s = bench("sherman_morrison_toggle_64x64", 2, 20, || {
        solver.solve_with_toggle(31, 17, true).unwrap();
    });
    record("sherman_morrison_toggle_64x64", s);
    let planes128 = random_planes(128, 128, 0.2, &mut rng);
    let c128 = CrossbarCircuit::from_planes(&planes128, physics)?;
    let s = bench("solve_cholesky_128x128", 0, 2, || {
        c128.solve().unwrap();
    });
    record("solve_cholesky_128x128", s);

    println!("\n== mapping pipeline =======================================================");
    let wdata: Vec<f32> = (0..512 * 64).map(|_| rng.laplace(0.2).abs() as f32).collect();
    let w = Tensor::new(&[512, 64], wdata)?;
    let s = bench("bitslice_512x64_k8", 1, 10, || {
        BitSlicedMatrix::slice(&w, 8).unwrap();
    });
    record("bitslice_512x64_k8", s);
    let sliced = BitSlicedMatrix::slice(&w, 8)?;
    let mdm = strategy_by_name("mdm")?;
    let s = bench("mdm_plan_tile_512x512", 1, 10, || {
        plan_tile(mdm.as_ref(), &sliced);
    });
    record("mdm_plan_tile_512x512", s);
    let plan = plan_tile(mdm.as_ref(), &sliced);
    let s = bench("plan_apply_512x512", 1, 10, || {
        plan.apply(&sliced.planes).unwrap();
    });
    record("plan_apply_512x512", s);
    let s = bench("eq17_distorted_weights_512x512", 1, 10, || {
        distorted_weights(&sliced, &plan, -2e-3).unwrap();
    });
    record("eq17_distorted_weights_512x512", s);

    println!("\n== tensor core ============================================================");
    let a_data: Vec<f32> = (0..64 * 512).map(|_| rng.uniform() as f32).collect();
    let a = Tensor::new(&[64, 512], a_data)?;
    let b_data: Vec<f32> = (0..512 * 512).map(|_| rng.uniform() as f32).collect();
    let b = Tensor::new(&[512, 512], b_data)?;
    let s = bench("matmul_64x512x512", 1, 5, || {
        a.matmul(&b).unwrap();
    });
    record("matmul_64x512x512", s);

    if Path::new("artifacts/manifest.txt").exists() {
        println!("\n== runtime + serving (PJRT) ===============================================");
        let store = ArtifactStore::open("artifacts")?;
        let kernel = store.load("noisy_tile_mvm_64x64")?;
        let x = Tensor::new(&[8, 64], (0..512).map(|i| i as f32 / 512.0).collect())?;
        let dist = mdm_cim::nf::distance_matrix(64, 64);
        let scales = Tensor::from_vec(sliced.col_scales()[..64].to_vec());
        let planes_t = random_planes(64, 64, 0.2, &mut rng);
        let eta = Tensor::new(&[1, 1], vec![-2e-3])?;
        let s = bench("pjrt_noisy_kernel_dispatch", 2, 20, || {
            kernel.run1(&[&x, &planes_t, &dist, &scales, &eta]).unwrap();
        });
        record("pjrt_noisy_kernel_dispatch", s);
        drop(store);

        let engine = Engine::program(
            "artifacts",
            EngineConfig {
                model: ModelKind::MiniResNet,
                strategy: mdm.clone(),
                estimator: mdm_cim::nf::estimator::estimator_by_name("analytic")?,
                eta_signed: -2e-3,
                geometry: TileGeometry::paper_eval(),
                fwd_batch: 16,
                solver_parallel: mdm_cim::parallel::ParallelConfig::default(),
                artifact_store: None,
            },
        )?;
        let test = ArtifactStore::open("artifacts")?.data("test")?;
        let (xb, _) = test.batch(0, 16);
        let s = bench("engine_infer_batch16", 2, 20, || {
            engine.infer(&xb).unwrap();
        });
        record("engine_infer_batch16", s);
        let s = bench("engine_program_miniresnet", 0, 2, || {
            Engine::program(
                "artifacts",
                EngineConfig {
                    model: ModelKind::MiniResNet,
                    strategy: mdm.clone(),
                    estimator: mdm_cim::nf::estimator::estimator_by_name("analytic").unwrap(),
                    eta_signed: -2e-3,
                    geometry: TileGeometry::paper_eval(),
                    fwd_batch: 16,
                    solver_parallel: mdm_cim::parallel::ParallelConfig::default(),
                    // Cold on purpose: this measures the full programming path.
                    artifact_store: None,
                },
            )
            .unwrap();
        });
        record("engine_program_miniresnet", s);
    } else {
        println!("\n(runtime/serving benches skipped: run `make artifacts`)");
    }

    write_csv(out.join("hotpath_timings.csv"), &["bench", "mean_s", "std_s", "min_s"], &timing)?;
    println!("\ntimings: results/bench/hotpath_timings.csv");
    Ok(())
}
