//! `cargo bench` — regenerates every figure of the paper's evaluation and
//! times the regeneration (hand-rolled harness; criterion unavailable
//! offline, see DESIGN.md §5).
//!
//! One section per figure:
//!   Fig. 2 — single-cell NF heatmap (circuit solver, Sherman–Morrison)
//!   Fig. 4 — Manhattan-Hypothesis fit on random tiles
//!   Fig. 5 — NF reduction across the model zoo
//!   Fig. 6 — accuracy under PR noise via the PJRT forward path
//!   A1–A3 + roworder — the ablations
//!
//! Results (both the measured figures and the timings) land under
//! `results/bench/`.

use mdm_cim::coordinator::ModelKind;
use mdm_cim::crossbar::TileGeometry;
use mdm_cim::eval;
use mdm_cim::report::write_csv;
use mdm_cim::testsupport::bench;
use mdm_cim::CrossbarPhysics;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let out = Path::new("results/bench");
    std::fs::create_dir_all(out)?;
    let mut timing: Vec<Vec<String>> = Vec::new();
    let mut record = |name: &str, s: mdm_cim::testsupport::BenchStats| {
        timing.push(vec![
            name.to_string(),
            format!("{:.6}", s.mean_s),
            format!("{:.6}", s.std_s),
            format!("{:.6}", s.min_s),
        ]);
    };

    println!("== Fig. 2: single-cell NF heatmap =========================================");
    let mut fig2 = None;
    let s = bench("fig2_heatmap_32x32", 0, 3, || {
        fig2 = Some(eval::fig2::run(32, CrossbarPhysics::default(), out).unwrap());
    });
    record("fig2_heatmap_32x32", s);
    let f2 = fig2.unwrap();
    println!(
        "  -> asymmetry {:.2e}, slope {:.3e} (theory {:.3e}), r2 {:.5}",
        f2.max_asymmetry, f2.linear_fit.slope, f2.theory_slope, f2.linear_fit.r2
    );
    let s = bench("fig2_heatmap_64x64", 0, 1, || {
        eval::fig2::run(64, CrossbarPhysics::default(), out).unwrap();
    });
    record("fig2_heatmap_64x64", s);

    println!("\n== Fig. 4: Manhattan-Hypothesis fit =======================================");
    let mut fig4 = None;
    let cfg4 = eval::fig4::Fig4Config { n_tiles: 100, tile: 64, ..Default::default() };
    let s = bench("fig4_fit_100x64x64", 0, 1, || {
        fig4 = Some(eval::fig4::run(cfg4.clone(), out).unwrap());
    });
    record("fig4_fit_100x64x64", s);
    let f4 = fig4.unwrap();
    println!(
        "  -> r2 {:.4}, error mu {:.3}% sigma {:.3}%  (paper: -0.126%, 11.2%)",
        f4.fit.fit.r2, f4.fit.error_summary.mean, f4.fit.error_summary.std
    );

    println!("\n== Fig. 5: NF reduction across the zoo ====================================");
    let mut fig5 = None;
    let cfg5 = eval::fig5::Fig5Config {
        tiles_per_layer: 16,
        artifacts_dir: Some("artifacts".into()),
        ..Default::default()
    };
    let s = bench("fig5_nf_zoo", 0, 1, || {
        fig5 = Some(eval::fig5::run(&cfg5, out).unwrap());
    });
    record("fig5_nf_zoo", s);
    for r in fig5.as_ref().unwrap() {
        println!(
            "  -> {:<12} mdm@conv {:>5.1}%  mdm@rev {:>5.1}%  full {:>5.1}%",
            r.model,
            r.reduction_conventional(),
            r.reduction_reversed(),
            r.reduction_full()
        );
    }

    println!("\n== Fig. 6: accuracy under PR noise (PJRT path) ============================");
    if Path::new("artifacts/manifest.txt").exists() {
        let mut fig6 = None;
        let s = bench("fig6_accuracy_both_models", 0, 1, || {
            fig6 = Some(
                eval::fig6::run(
                    "artifacts",
                    &[ModelKind::MiniResNet, ModelKind::TinyViT],
                    -2e-3,
                    TileGeometry::paper_eval(),
                    mdm_cim::parallel::ParallelConfig::default(),
                    out,
                )
                .unwrap(),
            );
        });
        record("fig6_accuracy_both_models", s);
        for r in fig6.as_ref().unwrap() {
            println!("  -> {:<12} {:<22} {:.2}%", r.model, r.config, 100.0 * r.accuracy);
        }
    } else {
        println!("  (skipped: run `make artifacts` first)");
    }

    println!("\n== Ablations ==============================================================");
    let s = bench("ablation_tilesize", 0, 1, || {
        eval::ablations::tile_size_sweep(&[16, 32, 64, 128], 8, 42, out).unwrap();
    });
    record("ablation_tilesize", s);
    let s = bench("ablation_sparsity", 0, 1, || {
        eval::ablations::sparsity_sweep(&[0.5, 0.7, 0.8, 0.9, 0.95], 64, 12, 42, out).unwrap();
    });
    record("ablation_sparsity", s);
    let s = bench("ablation_ratio", 0, 1, || {
        eval::ablations::ratio_sweep(&[0.5, 2.5, 10.0], 32, 24, 42, out).unwrap();
    });
    record("ablation_ratio", s);
    let s = bench("ablation_roworder", 0, 1, || {
        eval::ablations::roworder_compare(64, 8, 12, 42, out).unwrap();
    });
    record("ablation_roworder", s);
    let s = bench("eta_calibration", 0, 1, || {
        eval::calibrate::run(40, 32, 0.8, CrossbarPhysics::default(), 42, out).unwrap();
    });
    record("eta_calibration", s);
    let s = bench("ablation_global_sort", 0, 1, || {
        eval::ablations::global_sort_compare(512, 64, 8, 42, out).unwrap();
    });
    record("ablation_global_sort", s);
    let s = bench("ablation_variation", 0, 1, || {
        eval::ablations::variation_sweep(&[0.1, 0.3], 16, 8, 42, out).unwrap();
    });
    record("ablation_variation", s);
    let s = bench("ablation_faults", 0, 1, || {
        eval::ablations::fault_sweep(&[0.01, 0.05, 0.1], 64, 8, 6, 42, out).unwrap();
    });
    record("ablation_faults", s);

    write_csv(out.join("bench_timings.csv"), &["bench", "mean_s", "std_s", "min_s"], &timing)?;
    println!("\ntimings: results/bench/bench_timings.csv");
    Ok(())
}
