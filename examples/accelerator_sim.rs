//! Serving driver: batched inference requests through the coordinator.
//!
//! ```bash
//! make artifacts && cargo run --release --example accelerator_sim
//! ```
//!
//! Submits a closed-loop request stream against the thread-pool server for
//! each (tile size × mapping) configuration and reports throughput, latency
//! percentiles, and the analog cost model (ADC conversions, sync barriers)
//! — the paper's system-level trade-off (§I): small tiles cost conversions
//! and synchronization; MDM's NF reduction is what lets tiles grow.

use mdm_cim::config::ServerConfig;
use mdm_cim::coordinator::{EngineConfig, ModelKind, Server};
use mdm_cim::crossbar::TileGeometry;
use mdm_cim::mdm::strategy_by_name;
use mdm_cim::runtime::ArtifactStore;

const REQUESTS: usize = 96;
const ROWS_PER_REQ: usize = 4;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let test = ArtifactStore::open(&artifacts)?.data("test")?;

    println!(
        "{:>5} {:>13} {:>9} {:>9} {:>9} {:>12} {:>10}",
        "tile", "mapping", "req/s", "p50 ms", "p99 ms", "ADC/input", "sync/input"
    );
    let mut csv = Vec::new();
    for tile in [16usize, 32, 64] {
        for label in ["conventional", "mdm"] {
            let engine_cfg = EngineConfig {
                model: ModelKind::MiniResNet,
                strategy: strategy_by_name(label)?,
                estimator: mdm_cim::nf::estimator::estimator_by_name("analytic")?,
                eta_signed: -2e-3,
                geometry: TileGeometry::new(tile, tile, 8)?,
                fwd_batch: 16,
                solver_parallel: mdm_cim::parallel::ParallelConfig::default(),
                artifact_store: None,
            };
            let server = Server::start(
                &artifacts,
                engine_cfg,
                ServerConfig { workers: 2, max_batch: 16, batch_window_us: 200, queue_depth: 512 },
            )?;
            let t0 = std::time::Instant::now();
            let mut receivers = Vec::new();
            for i in 0..REQUESTS {
                let (x, _) = test.batch(i * ROWS_PER_REQ, ROWS_PER_REQ);
                receivers.push(server.submit(x)?);
            }
            let mut ok = 0usize;
            for rx in receivers {
                if rx.recv().is_ok() {
                    ok += 1;
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            let snap = server.metrics().snapshot();
            server.shutdown();
            let adc_per_input = snap.adc_conversions / snap.rows.max(1);
            let sync_per_input = snap.sync_events / snap.rows.max(1);
            println!(
                "{:>5} {:>13} {:>9.1} {:>9.2} {:>9.2} {:>12} {:>10}",
                tile,
                label,
                ok as f64 / dt,
                snap.latency_p50_us as f64 / 1000.0,
                snap.latency_p99_us as f64 / 1000.0,
                adc_per_input,
                sync_per_input
            );
            csv.push(vec![
                tile.to_string(),
                label.to_string(),
                format!("{:.2}", ok as f64 / dt),
                format!("{}", snap.latency_p50_us),
                format!("{}", snap.latency_p99_us),
                adc_per_input.to_string(),
                sync_per_input.to_string(),
            ]);
        }
    }
    std::fs::create_dir_all("results")?;
    mdm_cim::report::write_csv(
        "results/accelerator_sim.csv",
        &["tile", "mapping", "req_per_s", "p50_us", "p99_us", "adc_per_input", "sync_per_input"],
        &csv,
    )?;
    println!("\ncsv: results/accelerator_sim.csv");
    Ok(())
}
