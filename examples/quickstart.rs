//! Quickstart: program one weight matrix through the compile pipeline and
//! see the NF and the weight distortion drop under MDM.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — this exercises the pure-Rust path end to end:
//! bell-shaped weights → `Pipeline` (quantize → bit-slice → tile → map →
//! distort) → `ProgrammedLayer`, once with the conventional baseline and
//! once with the paper's MDM strategy (both selected by registry name).

use mdm_cim::crossbar::TileGeometry;
use mdm_cim::mdm::{plan_tile, strategy_by_name};
use mdm_cim::models::{generate_layer_weights, WeightProfile};
use mdm_cim::pipeline::{Pipeline, ProgrammedLayer};
use mdm_cim::quant::{BitSlicedMatrix, SignSplit};
use mdm_cim::report;
use mdm_cim::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    // A 64x8 layer slice with a realistic CNN weight distribution.
    let w = generate_layer_weights(64, 8, &WeightProfile::cnn(), 42)?;
    println!("weights: {:?}, {:.1}% exactly zero", w.shape(), 100.0 * w.sparsity());

    let geometry = TileGeometry::paper_eval();
    let physics = mdm_cim::CrossbarPhysics::default();
    let eta = -2e-3;

    // 1. One compile call per configuration: sign-split, bit-slice, tile,
    //    map with the named strategy, apply Eq.-17 PR distortion — cached
    //    into a ProgrammedLayer, exactly like flashing a CIM chip.
    let clean = Pipeline::new(geometry).compile(&w)?; // eta 0 reference
    let conv = Pipeline::new(geometry)
        .strategy("conventional")?
        .physics(physics)
        .eta_signed(eta)
        .compile(&w)?;
    let mdm = Pipeline::new(geometry)
        .strategy("mdm")?
        .physics(physics)
        .eta_signed(eta)
        .compile(&w)?;
    println!(
        "programmed {} tiles per configuration as {}/{} (plans + conductances cached once)",
        conv.n_tiles(),
        conv.strategy,
        mdm.strategy,
    );

    // 2. Mean Manhattan NF of the sampled tiles under each strategy.
    let nf = |name: &str| -> anyhow::Result<f64> {
        let mut rng = Xoshiro256::seeded(7);
        let (sum, n) = Pipeline::new(geometry).strategy(name)?.sampled_nf(&w, 8, &mut rng)?;
        Ok(sum / n.max(1) as f64)
    };
    let nf_conv = nf("conventional")?;
    let nf_mdm = nf("mdm")?;
    println!("\nNF (conventional) = {:.3e}", nf_conv);
    println!("NF (MDM)          = {:.3e}", nf_mdm);
    println!("reduction         = {:.1}%", 100.0 * (1.0 - nf_mdm / nf_conv));

    // 3. What the accelerator actually serves: distortion of the effective
    //    weights relative to the clean (quantized, undistorted) program.
    let dist = |p: &ProgrammedLayer| -> f64 {
        p.effective_weights()
            .data()
            .iter()
            .zip(clean.effective_weights().data())
            .map(|(a, b)| ((a - b).abs()) as f64)
            .sum()
    };
    println!("\nEq.-17 weight distortion (sum |w' - w|):");
    println!("  conventional: {:.5}", dist(&conv));
    println!("  MDM:          {:.5}", dist(&mdm));

    // 4. Where did the active cells go? (darker = active)
    let split = SignSplit::of(&w);
    let sliced = BitSlicedMatrix::slice(&split.pos, geometry.k_bits)?;
    let conv_plan = plan_tile(&*strategy_by_name("conventional")?, &sliced);
    let mdm_plan = plan_tile(&*strategy_by_name("mdm")?, &sliced);
    println!("\nconventional layout:");
    println!("{}", report::heatmap(&conv_plan.apply(&sliced.planes)?));
    println!("MDM layout (dense rows pulled toward the I/O corner):");
    println!("{}", report::heatmap(&mdm_plan.apply(&sliced.planes)?));

    // 5. The invariant that makes MDM free: permuting activations in and
    //    un-permuting outputs leaves the product unchanged (same plan as
    //    the layout above).
    let x = generate_layer_weights(1, sliced.rows(), &WeightProfile::cnn(), 7)?;
    let y_ref = x.matmul(&sliced.planes)?;
    let y_mdm = mdm_plan.unapply_to_outputs(
        &mdm_plan.apply_to_activations(&x)?.matmul(&mdm_plan.apply(&sliced.planes)?)?,
    )?;
    let err: f32 = y_ref
        .data()
        .iter()
        .zip(y_mdm.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    println!("max |x@W - mdm_roundtrip| = {err:.2e} (arithmetic preserved)");
    Ok(())
}
