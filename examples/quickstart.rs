//! Quickstart: map one weight matrix with MDM and see the NF drop.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — this exercises the pure-Rust mapping path:
//! bell-shaped weights → sign split → bit-slice → MDM plan → Manhattan NF.

use mdm_cim::mdm::{map_tile, MappingConfig};
use mdm_cim::models::{generate_layer_weights, WeightProfile};
use mdm_cim::nf::manhattan_nf_mean;
use mdm_cim::quant::{BitSlicedMatrix, SignSplit};
use mdm_cim::report;

fn main() -> anyhow::Result<()> {
    // A 64x8 layer slice with a realistic CNN weight distribution.
    let w = generate_layer_weights(64, 8, &WeightProfile::cnn(), 42)?;
    println!("weights: {:?}, {:.1}% exactly zero", w.shape(), 100.0 * w.sparsity());

    // 1. Sign-split (differential columns) and bit-slice the positive part.
    let split = SignSplit::of(&w);
    let sliced = BitSlicedMatrix::slice(&split.pos, 8)?;
    println!(
        "bit-sliced: {}x{} cells, crossbar sparsity {:.1}%",
        sliced.rows(),
        sliced.cols(),
        100.0 * sliced.sparsity()
    );

    // 2. Build the conventional and MDM mapping plans.
    let conv = map_tile(&sliced.planes, MappingConfig::conventional());
    let mdm = map_tile(&sliced.planes, MappingConfig::mdm());

    // 3. Compare the Manhattan-model NF (unit parasitic ratio).
    let nf_conv = manhattan_nf_mean(&conv.apply(&sliced.planes)?, 1.0);
    let nf_mdm = manhattan_nf_mean(&mdm.apply(&sliced.planes)?, 1.0);
    println!("\nNF (conventional) = {:.3}", nf_conv);
    println!("NF (MDM)          = {:.3}", nf_mdm);
    println!("reduction         = {:.1}%", 100.0 * (1.0 - nf_mdm / nf_conv));

    // 4. Where did the active cells go? (darker = active)
    println!("\nconventional layout:");
    println!("{}", report::heatmap(&conv.apply(&sliced.planes)?));
    println!("MDM layout (dense rows pulled toward the I/O corner):");
    println!("{}", report::heatmap(&mdm.apply(&sliced.planes)?));

    // 5. The invariant that makes MDM free: the product is unchanged.
    let x = generate_layer_weights(1, 64, &WeightProfile::cnn(), 7)?;
    let y_ref = x.matmul(&split.pos)?;
    let y_mdm = mdm
        .unapply_to_outputs(&mdm.apply_to_activations(&x)?.matmul(&mdm.apply(&split.pos)?)?)?;
    let err: f32 = y_ref
        .data()
        .iter()
        .zip(y_mdm.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    println!("max |x@W - mdm_roundtrip| = {err:.2e} (arithmetic preserved)");
    Ok(())
}
