//! Fig. 2 reproduction: circuit-level single-cell NF heatmap.
//!
//! ```bash
//! cargo run --release --example spice_heatmap [size]
//! ```
//!
//! Solves the full crossbar R-mesh (the SPICE substitute) with exactly one
//! active cell at every position, renders the NF heatmap, checks the
//! anti-diagonal symmetry the paper demonstrates, and exports a SPICE
//! `.cir` deck of one configuration for external verification.

use mdm_cim::circuit::{netlist, CrossbarCircuit};
use mdm_cim::eval::fig2;
use mdm_cim::CrossbarPhysics;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let size: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let physics = CrossbarPhysics::default();
    println!(
        "solving {0}x{0} crossbar, r = {1} ohm, R_on = {2:.0} ohm (one solve per cell, \
         Sherman-Morrison fast path) ...",
        size, physics.r_wire, physics.r_on
    );
    let t0 = std::time::Instant::now();
    let r = fig2::run(size, physics, Path::new("results"))?;
    println!("done in {:.2}s\n", t0.elapsed().as_secs_f64());

    println!("{}", mdm_cim::report::heatmap(&r.nf_map));
    println!("max anti-diagonal asymmetry: {:.3e}", r.max_asymmetry);
    println!(
        "NF = {:.3e} * d_M + {:.2e}   (theory slope r/R_on = {:.3e}, r^2 = {:.6})",
        r.linear_fit.slope, r.linear_fit.intercept, r.theory_slope, r.linear_fit.r2
    );

    // Export a verifiable SPICE deck of the max-distance configuration.
    let mut c = CrossbarCircuit::new(size.min(16), size.min(16), physics)?;
    c.set_active(size.min(16) - 1, size.min(16) - 1, true);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/crossbar_corner.cir", netlist::to_spice(&c, &physics))?;
    println!("\nSPICE deck for external verification: results/crossbar_corner.cir");
    println!("heatmap csv: results/fig2_heatmap.csv");
    Ok(())
}
