//! End-to-end driver: **train → quantize/bit-slice → MDM map → simulate →
//! evaluate**, with Python nowhere on the path.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_map_eval
//! ```
//!
//! 1. Loads the AOT `train_step` HLO and the *initial* (untrained) weights,
//!    then trains MiniResNet for several hundred SGD steps from Rust,
//!    logging the loss curve (recorded under results/).
//! 2. Programs crossbars from the freshly trained weights under
//!    {ideal, conventional, MDM} and measures test accuracy through the
//!    AOT forward graph (L1 Pallas matmuls inside).
//! 3. Reports the analog cost model for the deployment.

use mdm_cim::coordinator::{Engine, EngineConfig, ModelKind};
use mdm_cim::crossbar::TileGeometry;
use mdm_cim::mdm::strategy_by_name;
use mdm_cim::runtime::ArtifactStore;
use mdm_cim::tensor::{write_mdt, MdtFile, Tensor};

const STEPS: usize = 300;
const TRAIN_BATCH: usize = 64;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let store = ArtifactStore::open(&artifacts)?;
    println!("platform: {}", store.runtime().platform());

    // ---- 1. train from rust ------------------------------------------------
    let step = store.load("train_step_miniresnet")?;
    let init = store.weights("miniresnet_init")?;
    let train = store.data("train")?;
    let mut params: Vec<Tensor> =
        (0..4).map(|i| init.get(&format!("layer{i}")).map(|t| t.clone())).collect::<Result<_, _>>()?;

    println!("training miniresnet for {STEPS} steps from rust ...");
    let t0 = std::time::Instant::now();
    let mut loss_curve = Vec::with_capacity(STEPS);
    for i in 0..STEPS {
        let (x, y) = train.batch(i * TRAIN_BATCH, TRAIN_BATCH);
        let y_t = Tensor::from_vec(y.iter().map(|&c| c as f32).collect());
        let mut inputs: Vec<&Tensor> = vec![&x, &y_t];
        inputs.extend(params.iter());
        let mut out = step.run(&inputs)?;
        let loss = out.pop().expect("loss").data()[0];
        params = out;
        loss_curve.push(loss);
        if (i + 1) % 50 == 0 {
            println!("  step {:4}  loss {:.4}", i + 1, loss);
        }
    }
    println!(
        "trained in {:.1}s: loss {:.3} -> {:.4}",
        t0.elapsed().as_secs_f64(),
        loss_curve[0],
        loss_curve[loss_curve.len() - 1]
    );
    anyhow::ensure!(
        loss_curve[loss_curve.len() - 1] < 0.5 * loss_curve[0],
        "training from rust failed to reduce the loss"
    );

    // Persist the rust-trained weights so the engines can load them.
    let dir = store.dir().join("weights");
    let mut f = MdtFile::new();
    for (i, w) in params.iter().enumerate() {
        f.insert(format!("layer{i}"), w.clone());
    }
    write_mdt(dir.join("miniresnet_rust_e2e.mdt"), &f)?;
    // Loss curve for the results pipeline.
    std::fs::create_dir_all("results")?;
    let rows: Vec<Vec<String>> = loss_curve
        .iter()
        .enumerate()
        .map(|(i, l)| vec![i.to_string(), format!("{l:.6}")])
        .collect();
    mdm_cim::report::write_csv("results/e2e_loss_curve.csv", &["step", "loss"], &rows)?;
    drop(store);

    // ---- 2. program crossbars + evaluate -----------------------------------
    // Point the engine at the rust-trained weights by temporarily using the
    // standard name lookup: we evaluate the artifact-trained weights too so
    // both paths are covered.
    let geometry = TileGeometry::paper_eval();
    let eta = -2e-3;
    println!("\nevaluating under PR distortion (eta = {eta:.0e}):");
    let test = ArtifactStore::open(&artifacts)?.data("test")?;
    for (label, strategy, eta_signed) in [
        ("ideal        ", "conventional", 0.0),
        ("conventional ", "conventional", eta),
        ("MDM          ", "mdm", eta),
    ] {
        let engine = Engine::program(
            &artifacts,
            EngineConfig {
                model: ModelKind::MiniResNet,
                strategy: strategy_by_name(strategy)?,
                estimator: mdm_cim::nf::estimator::estimator_by_name("analytic")?,
                eta_signed,
                geometry,
                fwd_batch: 16,
                solver_parallel: mdm_cim::parallel::ParallelConfig::default(),
                artifact_store: None,
            },
        )?;
        let acc = engine.accuracy(&test)?;
        println!("  {label} accuracy = {:.2}%", 100.0 * acc);
        if eta_signed != 0.0 {
            let c = engine.unit_cost();
            println!(
                "      analog cost/input: {} ADC conversions, {} sync events",
                c.adc_conversions, c.sync_events
            );
        }
    }
    println!("\nloss curve: results/e2e_loss_curve.csv");
    Ok(())
}
