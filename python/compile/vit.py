"""L2: TinyViT — a two-block single-head vision transformer on the
synthetic task.

Bias-free and scale-free (RMS normalization without learned gain) so that
*every* parameter is a plain weight matrix mappable to crossbar tiles; the
layer export order matches ``rust/src/models/zoo.rs::tinyvit``.

Architecture (16x16 images as 16 patches of 4x4 = 16 dims, d = 64):

    patch embed   16 -> 64
    2 x [ single-head attention (qkv 64->192, proj 64->64)
          + MLP (64 -> 256 -> 64) ], pre-RMS-norm, residual
    mean-pool -> head 64 -> 10
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

N_PATCHES = 16
PATCH_DIM = 16
DIM = 64


def _positional_encoding() -> jnp.ndarray:
    """Fixed sinusoidal positional encoding ``[N_PATCHES, DIM]`` —
    parameter-free so the crossbar-mapped weight set stays pure matrices."""
    import numpy as np

    pos = np.zeros((N_PATCHES, DIM), np.float32)
    for p in range(N_PATCHES):
        for i in range(DIM // 2):
            ang = p / (10000.0 ** (2 * i / DIM))
            pos[p, 2 * i] = np.sin(ang)
            pos[p, 2 * i + 1] = np.cos(ang)
    return jnp.asarray(pos)


_POS = _positional_encoding()

#: (fan_in, fan_out) per weight, export order = layer{i}.
LAYER_SHAPES = [
    (PATCH_DIM, DIM),  # patch embed
    (DIM, 3 * DIM),    # block 1 qkv
    (DIM, DIM),        # block 1 proj
    (DIM, 4 * DIM),    # block 1 mlp up
    (4 * DIM, DIM),    # block 1 mlp down
    (DIM, 3 * DIM),    # block 2 qkv
    (DIM, DIM),        # block 2 proj
    (DIM, 4 * DIM),    # block 2 mlp up
    (4 * DIM, DIM),    # block 2 mlp down
    (DIM, 10),         # head
]


def init_params(seed: int) -> list[jnp.ndarray]:
    """Xavier-style init, deterministic in ``seed``."""
    key = jax.random.PRNGKey(seed)
    params = []
    for fan_in, fan_out in LAYER_SHAPES:
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_in, fan_out), jnp.float32)
        params.append(w * jnp.sqrt(1.0 / fan_in))
    return params


def _rms_norm(h):
    return h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + 1e-6)


def _block(h, w_qkv, w_proj, w_up, w_down, matmul):
    """Pre-norm single-head attention + MLP, both residual."""
    b, p, d = h.shape

    def mm(a, w):
        # Collapse the patch axis so the (pallas) matmul stays 2-D.
        return matmul(a.reshape(b * p, -1), w).reshape(b, p, -1)

    n = _rms_norm(h)
    qkv = mm(n, w_qkv)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    att = jnp.einsum("bpd,bqd->bpq", q, k) / jnp.sqrt(jnp.float32(d))
    att = jax.nn.softmax(att, axis=-1)
    h = h + mm(jnp.einsum("bpq,bqd->bpd", att, v), w_proj)

    n = _rms_norm(h)
    h = h + mm(jax.nn.relu(mm(n, w_up)), w_down)
    return h


def forward(params, x, matmul=jnp.matmul):
    """Logits ``[B, 10]`` for inputs ``[B, 256]``."""
    (w_embed, q1, p1, u1, d1, q2, p2, u2, d2, w_head) = params
    b = x.shape[0]
    patches = x.reshape(b, 4, 4, 4, 4).transpose(0, 1, 3, 2, 4).reshape(
        b * N_PATCHES, PATCH_DIM
    )
    h = matmul(patches, w_embed).reshape(b, N_PATCHES, DIM) + _POS
    h = _block(h, q1, p1, u1, d1, matmul)
    h = _block(h, q2, p2, u2, d2, matmul)
    pooled = _rms_norm(h.mean(axis=1))
    return matmul(pooled, w_head)
