"""AOT build: lower every L2 entry point to HLO text and export weights +
data shards for the Rust runtime.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts``:  ``cd python && python -m compile.aot --outdir
../artifacts``. Python never runs again after this.

Artifacts:
    miniresnet_fwd.hlo.txt       logits = fwd(x[B,256], w0..w3)  (pallas matmul)
    tinyvit_fwd.hlo.txt          logits = fwd(x[B,256], w0..w9)  (pallas matmul)
    train_step_miniresnet.hlo.txt  (w0..w3, x[Bt,256], y[Bt]) -> (w0..w3, loss)
    noisy_tile_mvm_64x64.hlo.txt   the L1 kernel standalone (B=8 tile MVM)
    bitslice_64x8.hlo.txt          the bit-slice kernel standalone
    weights/{miniresnet,tinyvit}{,_init}.mdt   layer{i} tensors
    data/{train,test}.mdt          synthetic dataset shards (x, y)
    manifest.txt                   name, file, input shapes, output shapes
"""

from __future__ import annotations

import argparse
import functools
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset, mdt, model, train, vit
from .kernels.bitslice import bitslice
from .kernels.matmul import matmul as pallas_matmul
from .kernels.noisy_mvm import noisy_tile_mvm

# Fixed AOT batch sizes (the coordinator pads to these).
FWD_BATCH = 16
TRAIN_BATCH = 64
KERNEL_BATCH = 8
TILE = 64
K_BITS = 8

SEED = 42
# noise 2.2 puts the trained models at ~94-97% test accuracy — high enough
# to be "trained", low enough that PR distortion visibly degrades Fig. 6.
N_TRAIN, N_TEST, NOISE = 2048, 512, 2.2


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (see module docstring).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big literals as ``constant({...})``, which the text parser then
    silently reads back as zeros — any model with an embedded constant
    (e.g. TinyViT's positional encoding) would run but compute garbage.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_entry(fn, specs):
    return to_hlo_text(jax.jit(fn).lower(*specs))


def export_model_weights(outdir: Path, name: str, params) -> None:
    mdt.write_mdt(
        outdir / "weights" / f"{name}.mdt",
        {f"layer{i}": np.asarray(w) for i, w in enumerate(params)},
    )


def build(outdir: Path, *, train_steps: int, quick: bool) -> None:
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "weights").mkdir(exist_ok=True)
    (outdir / "data").mkdir(exist_ok=True)
    manifest: list[str] = []

    def emit(name: str, fn, specs, note: str = ""):
        text = lower_entry(fn, specs)
        path = outdir / f"{name}.hlo.txt"
        path.write_text(text)
        shapes = ";".join(str(tuple(s.shape)) for s in jax.tree.leaves(specs))
        manifest.append(f"{name}\t{path.name}\t{shapes}\t{note}")
        print(f"  wrote {path.name} ({len(text)} chars)")

    # ---- dataset ----------------------------------------------------------
    print("generating dataset shards ...")
    xtr, ytr = dataset.generate(N_TRAIN, NOISE, SEED)
    xte, yte = dataset.generate(N_TEST, NOISE, SEED + 1, proto_seed=SEED)
    mdt.write_mdt(outdir / "data" / "train.mdt", {"x": xtr, "y": ytr})
    mdt.write_mdt(outdir / "data" / "test.mdt", {"x": xte, "y": yte})

    # ---- train the two models --------------------------------------------
    print("training miniresnet ...")
    p0 = model.init_params(SEED)
    export_model_weights(outdir, "miniresnet_init", p0)
    steps = train_steps if not quick else 50
    p_mini, losses = train.train(
        model.forward, p0, jnp.asarray(xtr), jnp.asarray(ytr),
        lr=0.05, steps=steps, batch=TRAIN_BATCH, log_every=max(steps // 5, 1),
    )
    acc = train.accuracy(model.forward, p_mini, jnp.asarray(xte), jnp.asarray(yte))
    print(f"  miniresnet test accuracy: {acc:.3f} (loss {losses[0]:.3f} -> {losses[-1]:.3f})")
    export_model_weights(outdir, "miniresnet", p_mini)

    print("training tinyvit ...")
    v0 = vit.init_params(SEED)
    export_model_weights(outdir, "tinyvit_init", v0)
    p_vit, vlosses = train.train(
        vit.forward, v0, jnp.asarray(xtr), jnp.asarray(ytr),
        lr=0.08, steps=steps + steps // 2, batch=TRAIN_BATCH,
        log_every=max(steps // 5, 1),
    )
    vacc = train.accuracy(vit.forward, p_vit, jnp.asarray(xte), jnp.asarray(yte))
    print(f"  tinyvit test accuracy: {vacc:.3f} (loss {vlosses[0]:.3f} -> {vlosses[-1]:.3f})")
    export_model_weights(outdir, "tinyvit", p_vit)

    with open(outdir / "train_log.txt", "w") as f:
        f.write(f"miniresnet steps={steps} acc={acc:.4f}\n")
        for i, l in enumerate(losses):
            f.write(f"mini {i} {l:.6f}\n")
        f.write(f"tinyvit steps={steps} acc={vacc:.4f}\n")
        for i, l in enumerate(vlosses):
            f.write(f"vit {i} {l:.6f}\n")

    # ---- forward graphs (weights as runtime inputs, pallas matmul) --------
    print("lowering forward graphs ...")

    def mini_fwd(x, *ws):
        return (model.forward(list(ws), x, matmul=pallas_matmul),)

    emit(
        "miniresnet_fwd",
        mini_fwd,
        [_spec((FWD_BATCH, 256))] + [_spec(s) for s in model.LAYER_SHAPES],
        "logits[B,10]",
    )

    def vit_fwd(x, *ws):
        return (vit.forward(list(ws), x, matmul=pallas_matmul),)

    emit(
        "tinyvit_fwd",
        vit_fwd,
        [_spec((FWD_BATCH, 256))] + [_spec(s) for s in vit.LAYER_SHAPES],
        "logits[B,10]",
    )

    # ---- train step (donated params; see DESIGN.md §Perf L2) --------------
    def train_step(x, y, *ws):
        step_params, loss = _train_step_impl(list(ws), x, y)
        return tuple(step_params) + (loss,)

    def _train_step_impl(params, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: train.cross_entropy(model.forward(p, x), y)
        )(params)
        return [w - 0.05 * g for w, g in zip(params, grads)], loss

    emit(
        "train_step_miniresnet",
        train_step,
        [_spec((TRAIN_BATCH, 256)), _spec((TRAIN_BATCH,))]
        + [_spec(s) for s in model.LAYER_SHAPES],
        "(w0..w3, loss)",
    )

    # ---- L1 kernels standalone --------------------------------------------
    print("lowering kernels ...")
    emit(
        "noisy_tile_mvm_64x64",
        functools.partial(
            lambda x, planes, d, s, eta: (
                noisy_tile_mvm(x, planes, d, s, eta, k_bits=K_BITS),
            )
        ),
        [
            _spec((KERNEL_BATCH, TILE)),
            _spec((TILE, TILE)),
            _spec((TILE, TILE)),
            _spec((TILE,)),
            _spec((1, 1)),
        ],
        "y[B,8]",
    )
    emit(
        "bitslice_64x8",
        lambda levels: (bitslice(levels, k_bits=K_BITS),),
        [_spec((TILE, TILE // K_BITS))],
        "planes[64,64]",
    )

    (outdir / "manifest.txt").write_text("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} entries")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--quick", action="store_true", help="50 train steps (tests)")
    args = ap.parse_args()
    build(Path(args.outdir), train_steps=args.train_steps, quick=args.quick)


if __name__ == "__main__":
    main()
