"""L2: training — cross-entropy SGD for MiniResNet / TinyViT.

Runs once at build time inside ``aot.py`` (the trained weights are exported
to ``artifacts/weights/``) and is itself AOT-lowered as ``train_step`` so
the Rust end-to-end example (`examples/e2e_train_map_eval.rs`) can train
the model from the coordinator without any Python on the path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy; ``labels`` are integer class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1).mean()


def make_train_step(forward, lr: float):
    """Plain-SGD train step: ``(params, x, y) -> (new_params, loss)``.

    ``y`` is float (class index) because the `.mdt` interchange format is
    f32-only; it is cast to int inside.
    """

    def loss_fn(params, x, y):
        return cross_entropy(forward(params, x), y)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(params, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_params = [w - lr * g for w, g in zip(params, grads)]
        return new_params, loss

    return step


def accuracy(forward, params, x: jnp.ndarray, y: jnp.ndarray) -> float:
    """Top-1 accuracy."""
    pred = jnp.argmax(forward(params, x), axis=-1)
    return float((pred == y.astype(jnp.int32)).mean())


def train(forward, params, x, y, *, lr: float, steps: int, batch: int, log_every: int = 0):
    """Minibatch SGD over a fixed split (wrapping batches, matching the
    deterministic schedule the Rust e2e driver replays)."""
    step = make_train_step(forward, lr)
    n = x.shape[0]
    losses = []
    for i in range(steps):
        lo = (i * batch) % n
        idx = jnp.asarray([(lo + j) % n for j in range(batch)], dtype=jnp.int32)
        xb, yb = x[idx], y[idx]
        params, loss = step(params, xb, yb)
        losses.append(float(loss))
        if log_every and (i + 1) % log_every == 0:
            print(f"  step {i + 1:4d}  loss {float(loss):.4f}")
    return params, losses
