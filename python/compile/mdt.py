"""`.mdt` tensor container — the Python half of the format shared with the
Rust runtime (`rust/src/tensor/io.rs`).

Layout (little-endian):

    magic   : 4 bytes  = b"MDT1"
    count   : u32
    entry*  :
      name_len : u32
      name     : utf-8
      dtype    : u8 (0 = f32)
      ndim     : u32
      dims     : ndim x u64
      data     : prod(dims) x f32, row-major
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"MDT1"
DTYPE_F32 = 0


def write_mdt(path: str | Path, tensors: dict[str, np.ndarray]) -> None:
    """Write named tensors; keys are sorted for deterministic files."""
    buf = bytearray()
    buf += MAGIC
    buf += struct.pack("<I", len(tensors))
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
        name_b = name.encode("utf-8")
        buf += struct.pack("<I", len(name_b))
        buf += name_b
        buf += struct.pack("<B", DTYPE_F32)
        buf += struct.pack("<I", arr.ndim)
        for d in arr.shape:
            buf += struct.pack("<Q", d)
        buf += arr.tobytes(order="C")
    tmp = Path(path).with_suffix(".mdt.tmp")
    tmp.write_bytes(bytes(buf))
    tmp.rename(path)


def read_mdt(path: str | Path) -> dict[str, np.ndarray]:
    """Read an `.mdt` file into name -> float32 ndarray."""
    data = Path(path).read_bytes()
    off = 0

    def take(n: int) -> bytes:
        nonlocal off
        if off + n > len(data):
            raise ValueError(f"truncated mdt file at offset {off}")
        out = data[off : off + n]
        off += n
        return out

    if take(4) != MAGIC:
        raise ValueError("bad mdt magic")
    (count,) = struct.unpack("<I", take(4))
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (name_len,) = struct.unpack("<I", take(4))
        if name_len > 4096:
            raise ValueError(f"unreasonable name length {name_len}")
        name = take(name_len).decode("utf-8")
        (dtype,) = struct.unpack("<B", take(1))
        if dtype != DTYPE_F32:
            raise ValueError(f"unsupported dtype {dtype}")
        (ndim,) = struct.unpack("<I", take(4))
        if ndim > 8:
            raise ValueError(f"unreasonable ndim {ndim}")
        dims = [struct.unpack("<Q", take(8))[0] for _ in range(ndim)]
        n = int(np.prod(dims)) if dims else 1
        arr = np.frombuffer(take(4 * n), dtype="<f4").reshape(dims)
        out[name] = arr.copy()
    return out
