"""L1 Pallas kernel: crossbar-tile MVM under position-dependent PR
distortion — the compute hot-spot of the whole stack.

The kernel fuses three steps that a naive implementation would materialize
separately:

1. Eq.-17 effective weights: ``eff = planes * (1 + eta * dist) * scales``
   (one fused multiply tree, no intermediate HBM traffic);
2. the tile MVM ``part = x @ eff`` (MXU-shaped dot);
3. the digital bit-column accumulation ``y[., w] = sum_b part[., w*K+b]``.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's "tile" is
an analog crossbar; on TPU the same dataflow is a VMEM-resident block
(`J×C` bit-planes + `B×J` activations) feeding the MXU. The grid iterates
over the contraction (row) dimension in ``block_j`` chunks so arbitrarily
tall tiles stream through VMEM — the BlockSpec plays the role the paper's
row-chunk tiling plays on the crossbar.

Must be lowered with ``interpret=True``: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, planes_ref, dist_ref, scales_ref, eta_ref, o_ref, *, k_bits: int):
    """One grid step: accumulate a row-chunk's contribution into o_ref."""
    jb = pl.program_id(0)

    @pl.when(jb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    eta = eta_ref[0, 0]
    # Fused Eq.-17 effective weight for this row-chunk.
    eff = planes_ref[...] * (1.0 + eta * dist_ref[...]) * scales_ref[...]
    part = jnp.dot(x_ref[...], eff, preferred_element_type=jnp.float32)
    b, c = part.shape
    o_ref[...] += part.reshape(b, c // k_bits, k_bits).sum(axis=-1)


def noisy_tile_mvm(
    x: jnp.ndarray,
    planes: jnp.ndarray,
    dist: jnp.ndarray,
    col_scales: jnp.ndarray,
    eta: jnp.ndarray,
    *,
    k_bits: int,
    block_j: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Crossbar-tile MVM under PR distortion.

    Args:
      x: activations ``[B, J]`` (f32).
      planes: binary bit-planes ``[J, C]``.
      dist: per-cell Manhattan distances ``[J, C]`` (from the mapping plan).
      col_scales: per-bit-column scales ``[C]``.
      eta: signed noise coefficient as a ``[1, 1]`` array (an input, so one
        compiled executable serves every operating point).
      k_bits: fractional bits per weight; ``C % k_bits == 0``.
      block_j: contraction-dimension block (default: whole ``J`` if it fits,
        else 128). Must divide ``J``.
      interpret: keep True anywhere the CPU PJRT client must run the HLO.

    Returns:
      ``[B, C // k_bits]`` partial products per logical weight column.
    """
    b, j = x.shape
    j2, c = planes.shape
    if j != j2:
        raise ValueError(f"x {x.shape} vs planes {planes.shape}")
    if c % k_bits != 0:
        raise ValueError(f"C={c} not divisible by k_bits={k_bits}")
    if block_j is None:
        block_j = j if j <= 256 else 128
    if j % block_j != 0:
        raise ValueError(f"J={j} not divisible by block_j={block_j}")
    n_weights = c // k_bits
    grid = (j // block_j,)
    return pl.pallas_call(
        functools.partial(_kernel, k_bits=k_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_j), lambda jb: (0, jb)),
            pl.BlockSpec((block_j, c), lambda jb: (jb, 0)),
            pl.BlockSpec((block_j, c), lambda jb: (jb, 0)),
            pl.BlockSpec((c,), lambda jb: (0,)),
            pl.BlockSpec((1, 1), lambda jb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, n_weights), lambda jb: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_weights), jnp.float32),
        interpret=interpret,
    )(x, planes, dist, col_scales, eta)


def vmem_footprint_bytes(b: int, j: int, c: int, k_bits: int, block_j: int) -> int:
    """Estimated VMEM working set of one grid step, bytes (fp32).

    Used by DESIGN.md §Perf to check the block shape stays well under the
    ~16 MiB/core VMEM budget of current TPUs.
    """
    del k_bits
    x_blk = b * block_j
    planes_blk = block_j * c
    dist_blk = block_j * c
    scales = c
    out = b * c  # part + out accumulator upper bound
    return 4 * (x_blk + planes_blk + dist_blk + scales + out)
