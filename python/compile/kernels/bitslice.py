"""L1 Pallas kernel: bit-slice integer magnitude levels into binary planes.

Build-path companion of the MVM kernels: given per-weight quantization
levels ``[J, N]`` (integers in ``[0, 2^K)`` stored as f32 — the analog
programming granularity), emit the ``[J, N*K]`` binary planes with the
MSB-first column convention shared with ``rust/src/quant``.

No data-dependent control flow: the bit extraction is a broadcasted
floor-divide/mod over a constant divisor vector, which vectorizes cleanly
on VPU lanes (and in interpret mode on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(levels_ref, o_ref, *, k_bits: int):
    levels = levels_ref[...]
    j, n = levels.shape
    # Build the divisor vector with an in-kernel iota (a captured ndarray
    # constant would be rejected by pallas_call).
    e = jax.lax.broadcasted_iota(jnp.float32, (k_bits,), 0)
    divisors = jnp.exp2(jnp.float32(k_bits - 1) - e)
    bits = jnp.floor_divide(levels[..., None], divisors) % 2.0
    o_ref[...] = bits.reshape(j, n * k_bits)


def bitslice(
    levels: jnp.ndarray,
    *,
    k_bits: int,
    block_j: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Slice ``[J, N]`` levels into ``[J, N*k_bits]`` binary planes."""
    j, n = levels.shape
    if block_j is None:
        block_j = j if j <= 512 else 256
    if j % block_j != 0:
        block_j = j  # fall back to a single row-block
    grid = (j // block_j,)
    return pl.pallas_call(
        functools.partial(_kernel, k_bits=k_bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_j, n), lambda jb: (jb, 0))],
        out_specs=pl.BlockSpec((block_j, n * k_bits), lambda jb: (jb, 0)),
        out_shape=jax.ShapeDtypeStruct((j, n * k_bits), jnp.float32),
        interpret=interpret,
    )(levels)
