"""L1 Pallas kernel: blocked matmul used by the L2 forward graphs.

A standard three-level blocked matmul (`grid = (M/bm, N/bn, K/bk)`, fp32
accumulation in the output block) — the MXU-shaped workhorse every layer of
the AOT'd forward passes lowers through. Falls back to single-block when a
dimension is not divisible by its block size (model dims here are small;
the head matrices have N = 10).

Lowered with ``interpret=True`` so the CPU PJRT client can run it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _pick(dim: int, want: int) -> int:
    """Largest block <= want that divides dim."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Blocked ``x [M, K] @ w [K, N] -> [M, N]`` Pallas matmul."""
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"matmul inner-dim mismatch {x.shape} @ {w.shape}")
    bm, bn, bk = _pick(m, bm), _pick(n, bn), _pick(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, jx, kx: (i, kx)),
            pl.BlockSpec((bk, bn), lambda i, jx, kx: (kx, jx)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, jx, kx: (i, jx)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)
