"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package has a reference implementation here written
with plain `jax.numpy` ops only; pytest sweeps shapes/dtypes/coefficients
(see ``python/tests/test_kernels.py``) and asserts allclose between kernel
and oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain matrix multiply, fp32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def ref_effective_weights(
    planes: jnp.ndarray,
    dist: jnp.ndarray,
    col_scales: jnp.ndarray,
    eta: float,
) -> jnp.ndarray:
    """Eq. 17 effective per-cell weight of a bit-sliced crossbar tile.

    ``planes``: binary ``[J, C]``; ``dist``: Manhattan distance of the
    physical cell holding each logical entry ``[J, C]``; ``col_scales``:
    per-column scale ``scale * 2^-(bit+1)`` of length ``C``; ``eta``: signed
    noise coefficient (the paper's calibrated operating point corresponds to
    ``-2e-3``).
    """
    return planes * (1.0 + eta * dist) * col_scales[None, :]


def ref_noisy_tile_mvm(
    x: jnp.ndarray,
    planes: jnp.ndarray,
    dist: jnp.ndarray,
    col_scales: jnp.ndarray,
    eta: float,
    k_bits: int,
) -> jnp.ndarray:
    """Crossbar-tile MVM under PR distortion.

    ``x``: activations ``[B, J]``; returns ``[B, C // k_bits]`` — partial
    products of the tile's logical weight columns, digitally accumulated
    over each weight's ``k_bits`` bit columns.
    """
    b, j = x.shape
    j2, c = planes.shape
    assert j == j2, (x.shape, planes.shape)
    assert c % k_bits == 0
    eff = ref_effective_weights(planes, dist, col_scales, eta)
    part = jnp.matmul(x, eff, preferred_element_type=jnp.float32)  # [B, C]
    return part.reshape(b, c // k_bits, k_bits).sum(axis=-1)


def ref_bitslice(levels: jnp.ndarray, k_bits: int) -> jnp.ndarray:
    """Bit-slice integer magnitude levels into binary planes.

    ``levels``: ``[J, N]`` float tensor holding integers in
    ``[0, 2^k_bits)``. Returns ``[J, N * k_bits]`` binary planes where local
    bit 0 is the highest-order fractional bit (``2^-1``) — the same column
    convention as ``rust/src/quant``.
    """
    j, n = levels.shape
    # divisor for local bit b (0 = MSB): 2^(k_bits-1-b)
    divisors = 2.0 ** jnp.arange(k_bits - 1, -1, -1, dtype=jnp.float32)
    bits = jnp.floor_divide(levels[..., None], divisors) % 2.0  # [J, N, K]
    return bits.reshape(j, n * k_bits)
