"""Synthetic dataset — exact Python port of ``rust/src/dataset.rs``.

Both sides implement the same SplitMix64-seeded xoshiro256** generator and
the same sampling order, so `generate(n, noise, seed)` here and
``dataset::generate`` in Rust produce the same values (up to libm ulp
differences in sin/cos/ln, i.e. identical to ~1e-6 after the f32 cast) —
the cross-language integration test in ``rust/tests`` checks this against
the shards `aot.py` exports.
"""

from __future__ import annotations

import math

import numpy as np

IMG_SIDE = 16
N_FEATURES = IMG_SIDE * IMG_SIDE
N_CLASSES = 10

_MASK = (1 << 64) - 1


class SplitMix64:
    """Seeder for xoshiro (Steele/Lea/Flood 2014)."""

    def __init__(self, seed: int):
        self.state = seed & _MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
        return z ^ (z >> 31)


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & _MASK


class Xoshiro256:
    """xoshiro256** with the same distribution helpers as the Rust side."""

    def __init__(self, seed: int):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]
        self._gauss_cache: float | None = None

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[1] * 5) & _MASK, 7) * 9) & _MASK
        t = (s[1] << 17) & _MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def uniform(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n: int) -> int:
        """Lemire multiply-shift with rejection (matches Rust exactly)."""
        threshold = (-n) % n if n else 0
        while True:
            r = self.next_u64()
            wide = r * n
            hi, lo = wide >> 64, wide & _MASK
            if lo >= threshold:
                return hi

    def normal(self) -> float:
        if self._gauss_cache is not None:
            z, self._gauss_cache = self._gauss_cache, None
            return z
        u = self.uniform()
        while u <= 2.2250738585072014e-308:
            u = self.uniform()
        v = self.uniform()
        r = math.sqrt(-2.0 * math.log(u))
        theta = 2.0 * math.pi * v
        self._gauss_cache = r * math.sin(theta)
        return r * math.cos(theta)


def class_prototypes(seed: int) -> np.ndarray:
    rng = Xoshiro256(seed)
    data = np.array(
        [rng.normal() for _ in range(N_CLASSES * N_FEATURES)], dtype=np.float32
    )
    return data.reshape(N_CLASSES, N_FEATURES)


def generate(
    n: int, noise: float, seed: int, proto_seed: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Same algorithm + sampling order as ``dataset::generate`` in Rust.

    ``proto_seed`` pins the class prototypes independently of the sample
    stream so train/test splits share classes (defaults to ``seed``).
    """
    protos = class_prototypes(seed if proto_seed is None else proto_seed)
    rng = Xoshiro256(seed ^ 0xDA7A5E7)
    x = np.zeros((n, N_FEATURES), dtype=np.float32)
    y = np.zeros((n,), dtype=np.float32)
    for i in range(n):
        c = rng.below(N_CLASSES)
        y[i] = c
        proto = protos[c]
        for f in range(N_FEATURES):
            x[i, f] = proto[f] + np.float32(rng.normal() * noise)
    return x, y
