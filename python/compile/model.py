"""L2: MiniResNet — the residual MLP-CNN trained on the synthetic task.

Bias-free (every parameter is a weight matrix that maps onto crossbar
tiles; see ``rust/src/models/zoo.rs::miniresnet`` for the matching layer
descriptors). The forward pass is parameterized over the matmul
implementation so the AOT'd inference graph routes every layer through the
L1 Pallas kernel while training uses plain ``jnp.matmul`` (autodiff through
interpret-mode pallas is possible but needlessly slow at build time).

Architecture (16x16 synthetic images, 10 classes):

    x [B, 256] -> relu(x @ W0)            stem    256 -> 128
               -> h + relu(h @ W1)        block1  128 -> 128
               -> h + relu(h @ W2)        block2  128 -> 128
               -> h @ W3                  head    128 -> 10
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: (fan_in, fan_out) of each weight, export order = layer{i} in the .mdt.
LAYER_SHAPES = [(256, 128), (128, 128), (128, 128), (128, 10)]


def init_params(seed: int) -> list[jnp.ndarray]:
    """He-style init, deterministic in ``seed``."""
    key = jax.random.PRNGKey(seed)
    params = []
    for fan_in, fan_out in LAYER_SHAPES:
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_in, fan_out), jnp.float32)
        params.append(w * jnp.sqrt(2.0 / fan_in))
    return params


def forward(params, x, matmul=jnp.matmul):
    """Logits ``[B, 10]`` for inputs ``[B, 256]``."""
    w0, w1, w2, w3 = params
    h = jax.nn.relu(matmul(x, w0))
    h = h + jax.nn.relu(matmul(h, w1))
    h = h + jax.nn.relu(matmul(h, w2))
    return matmul(h, w3)
