"""Empirical validation of Theorem 1 (bit-level structured sparsity).

For a non-negative random variable with continuous, strictly decreasing
density f on [0, inf) and f(0) < inf:

    |p_k - 1/2| <= f(0) / 2^(2+k)    and    p_k < 1/2 for every k,

where p_k is the probability the k-th fractional bit (value 2^-k) of W is
set. We check the bound for exponential and half-gaussian magnitudes
(the magnitude distributions of Laplace / Gaussian weights) using the exact
bit indicator of the theorem's proof (no quantization — quantization
round-to-nearest perturbs only the lowest bit).
"""

import numpy as np
import pytest


def exact_bit(w: np.ndarray, k: int) -> np.ndarray:
    """b_k(w): 1 on [mL + L/2, (m+1)L) with L = 2^-k."""
    L = 2.0 ** (-k)
    frac = np.mod(w, L) / L
    return (frac >= 0.5).astype(np.float64)


CASES = [
    # (name, sampler(rng, n), f(0))
    ("exponential(4)", lambda rng, n: rng.exponential(1 / 4.0, n), 4.0),
    ("exponential(1)", lambda rng, n: rng.exponential(1.0, n), 1.0),
    (
        "half-gaussian(0.5)",
        lambda rng, n: np.abs(rng.normal(0, 0.5, n)),
        2.0 / (0.5 * np.sqrt(2 * np.pi)),
    ),
]


@pytest.mark.parametrize("name,sampler,f0", CASES, ids=[c[0] for c in CASES])
def test_theorem1_bound_holds(name, sampler, f0):
    rng = np.random.default_rng(1234)
    n = 400_000
    w = sampler(rng, n)
    se = 3.0 / np.sqrt(n)  # 3-sigma sampling slack on p_k
    for k in range(1, 9):
        p_k = exact_bit(w, k).mean()
        bound = f0 / 2.0 ** (2 + k)
        assert abs(p_k - 0.5) <= bound + se, (
            f"{name}: k={k} p_k={p_k:.5f} violates |p-1/2|<={bound:.5f}"
        )
        # p_k < 1/2 strictly (up to sampling noise).
        assert p_k < 0.5 + se, f"{name}: k={k} p_k={p_k:.5f} not below 1/2"


def test_pk_converges_to_half():
    rng = np.random.default_rng(5)
    w = rng.exponential(0.25, 400_000)
    p1 = exact_bit(w, 1).mean()
    p8 = exact_bit(w, 8).mean()
    assert abs(p8 - 0.5) < abs(p1 - 0.5)
    assert abs(p8 - 0.5) < 0.01


def test_high_order_bits_sparser_after_quantization():
    """The consequence MDM uses: in an 8-bit sliced tile of bell-shaped
    weights, high-order columns are much sparser than low-order ones."""
    rng = np.random.default_rng(7)
    w = np.abs(rng.laplace(0, 0.05, 100_000))
    scale = w.max() * (1 + 1e-6)
    levels = np.clip(np.round(w / scale * 256), 0, 255).astype(np.int64)
    density = [(levels >> (8 - 1 - b) & 1).mean() for b in range(8)]
    assert density[0] < 0.05  # top bit almost never set
    assert density[6] > 0.3
    assert density[0] < density[3] < density[6]
