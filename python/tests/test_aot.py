"""AOT lowering tests: every entry point lowers to parseable HLO text with
the expected entry computation layout (no full artifact build here — that
is `make artifacts`; these tests exercise the lowering path itself)."""

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels.noisy_mvm import noisy_tile_mvm


def test_to_hlo_text_simple_fn():
    def fn(a, b):
        return (a @ b + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "entry_computation_layout" in text
    assert "f32[4,4]" in text


def test_fwd_entry_lowers_with_pallas():
    from compile.kernels.matmul import matmul as pallas_matmul

    def mini_fwd(x, *ws):
        return (model.forward(list(ws), x, matmul=pallas_matmul),)

    specs = [jax.ShapeDtypeStruct((aot.FWD_BATCH, 256), jnp.float32)] + [
        jax.ShapeDtypeStruct(s, jnp.float32) for s in model.LAYER_SHAPES
    ]
    text = aot.lower_entry(mini_fwd, specs)
    assert text.startswith("HloModule")
    # Output is a 1-tuple of [B, 10] logits.
    assert f"f32[{aot.FWD_BATCH},10]" in text
    # interpret-mode pallas lowers to plain HLO: no custom-call opcodes.
    assert "custom-call" not in text


def test_large_constants_not_elided():
    """Regression: the default HLO printer elides big literals as
    ``constant({...})`` which the 0.5.1 text parser reads back as zeros
    (this silently zeroed TinyViT's positional encoding). ``to_hlo_text``
    must print the full constant."""
    import jax.numpy as jnp

    big = jnp.arange(1024, dtype=jnp.float32).reshape(16, 64)

    def fn(x):
        return (x + big,)

    text = aot.lower_entry(fn, [jax.ShapeDtypeStruct((16, 64), jnp.float32)])
    assert "{...}" not in text, "elided constant in HLO text"
    assert "1023" in text  # the last literal value is present


def test_tinyvit_fwd_contains_positional_constant():
    from compile import vit
    from compile.kernels.matmul import matmul as pallas_matmul

    def vit_fwd(x, *ws):
        return (vit.forward(list(ws), x, matmul=pallas_matmul),)

    specs = [jax.ShapeDtypeStruct((aot.FWD_BATCH, 256), jnp.float32)] + [
        jax.ShapeDtypeStruct(s, jnp.float32) for s in vit.LAYER_SHAPES
    ]
    text = aot.lower_entry(vit_fwd, specs)
    assert "{...}" not in text


def test_noisy_kernel_entry_lowers():
    specs = [
        jax.ShapeDtypeStruct((aot.KERNEL_BATCH, aot.TILE), jnp.float32),
        jax.ShapeDtypeStruct((aot.TILE, aot.TILE), jnp.float32),
        jax.ShapeDtypeStruct((aot.TILE, aot.TILE), jnp.float32),
        jax.ShapeDtypeStruct((aot.TILE,), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
    ]
    text = aot.lower_entry(
        lambda x, p, d, s, e: (noisy_tile_mvm(x, p, d, s, e, k_bits=aot.K_BITS),),
        specs,
    )
    assert text.startswith("HloModule")
    assert "custom-call" not in text
    assert f"f32[{aot.KERNEL_BATCH},{aot.TILE // aot.K_BITS}]" in text
