"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes / blockings / noise coefficients with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.bitslice import bitslice
from compile.kernels.matmul import matmul
from compile.kernels.noisy_mvm import noisy_tile_mvm, vmem_footprint_bytes

hypothesis.settings.register_profile(
    "build", settings(max_examples=25, deadline=None)
)
hypothesis.settings.load_profile("build")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------- noisy mvm
@given(
    b=st.sampled_from([1, 4, 8]),
    j=st.sampled_from([16, 64, 128]),
    n_weights=st.sampled_from([2, 8]),
    k_bits=st.sampled_from([4, 8]),
    eta=st.sampled_from([0.0, -2e-3, 2e-3, -1e-2]),
    block_div=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_noisy_tile_mvm_matches_ref(b, j, n_weights, k_bits, eta, block_div, seed):
    rng = np.random.default_rng(seed)
    c = n_weights * k_bits
    x = _rand(rng, b, j)
    planes = jnp.asarray(rng.integers(0, 2, size=(j, c)), jnp.float32)
    # Arbitrary (plan-dependent) distance tensor, not just j+k.
    dist = jnp.asarray(rng.integers(0, j + c, size=(j, c)), jnp.float32)
    scales = jnp.asarray(0.5 ** (rng.integers(1, k_bits + 1, size=c)), jnp.float32)
    y = noisy_tile_mvm(
        x, planes, dist, scales, jnp.full((1, 1), eta, jnp.float32),
        k_bits=k_bits, block_j=j // block_div,
    )
    y_ref = ref.ref_noisy_tile_mvm(x, planes, dist, scales, eta, k_bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-5)


def test_noisy_tile_mvm_rejects_bad_shapes():
    x = jnp.zeros((2, 16))
    planes = jnp.zeros((8, 16))  # J mismatch
    dist = jnp.zeros((8, 16))
    scales = jnp.zeros((16,))
    eta = jnp.zeros((1, 1))
    with pytest.raises(ValueError):
        noisy_tile_mvm(x, planes, dist, scales, eta, k_bits=8)
    with pytest.raises(ValueError):
        noisy_tile_mvm(jnp.zeros((2, 8)), planes, dist, scales, eta, k_bits=3)
    with pytest.raises(ValueError):
        noisy_tile_mvm(jnp.zeros((2, 8)), planes, dist, scales, eta, k_bits=8, block_j=3)


def test_noisy_mvm_zero_eta_equals_clean_matmul():
    rng = np.random.default_rng(7)
    x = _rand(rng, 4, 64)
    planes = jnp.asarray(rng.integers(0, 2, size=(64, 64)), jnp.float32)
    dist = jnp.asarray(rng.integers(0, 128, size=(64, 64)), jnp.float32)
    scales = jnp.asarray(0.5 ** (np.arange(64) % 8 + 1), jnp.float32)
    y = noisy_tile_mvm(
        x, planes, dist, scales, jnp.zeros((1, 1), jnp.float32), k_bits=8
    )
    eff = np.asarray(planes) * np.asarray(scales)[None, :]
    part = np.asarray(x) @ eff
    y_ref = part.reshape(4, 8, 8).sum(-1)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-5, atol=1e-5)


def test_vmem_footprint_reasonable():
    # 64x64 tile, B=8, block_j=64: must sit far below 16 MiB VMEM.
    assert vmem_footprint_bytes(8, 64, 64, 8, 64) < 1 << 20


# ------------------------------------------------------------------- matmul
@given(
    m=st.sampled_from([1, 10, 16, 64]),
    k=st.sampled_from([16, 48, 256]),
    n=st.sampled_from([10, 64, 192]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    w = _rand(rng, k, n)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w)),
        np.asarray(ref.ref_matmul(x, w)),
        rtol=1e-4,
        atol=1e-4,
    )


def test_matmul_dim_mismatch():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


# ----------------------------------------------------------------- bitslice
@given(
    j=st.sampled_from([1, 32, 64]),
    n=st.sampled_from([1, 8]),
    k_bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitslice_matches_ref(j, n, k_bits, seed):
    rng = np.random.default_rng(seed)
    levels = jnp.asarray(rng.integers(0, 2**k_bits, size=(j, n)), jnp.float32)
    got = np.asarray(bitslice(levels, k_bits=k_bits))
    want = np.asarray(ref.ref_bitslice(levels, k_bits))
    np.testing.assert_array_equal(got, want)
    # And the planes must reconstruct the levels.
    weights = (2.0 ** np.arange(k_bits - 1, -1, -1))[None, None, :]
    recon = (got.reshape(j, n, k_bits) * weights).sum(-1)
    np.testing.assert_array_equal(recon, np.asarray(levels))


def test_bitslice_msb_first_convention():
    # Level 0b1010 = 10 -> planes [1, 0, 1, 0] with local bit 0 = MSB (2^-1).
    out = np.asarray(bitslice(jnp.asarray([[10.0]]), k_bits=4))
    np.testing.assert_array_equal(out, [[1.0, 0.0, 1.0, 0.0]])
