"""L2 model tests: shapes, training dynamics, pallas-matmul equivalence."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import dataset, model, train, vit
from compile.kernels.matmul import matmul as pallas_matmul


@pytest.fixture(scope="module")
def data():
    x, y = dataset.generate(512, 2.2, 42)
    xt, yt = dataset.generate(128, 2.2, 43, proto_seed=42)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(xt), jnp.asarray(yt)


def test_miniresnet_shapes():
    p = model.init_params(0)
    assert [tuple(w.shape) for w in p] == model.LAYER_SHAPES
    logits = model.forward(p, jnp.zeros((3, 256)))
    assert logits.shape == (3, 10)


def test_tinyvit_shapes():
    p = vit.init_params(0)
    assert [tuple(w.shape) for w in p] == vit.LAYER_SHAPES
    logits = vit.forward(p, jnp.zeros((3, 256)))
    assert logits.shape == (3, 10)


def test_forward_pallas_equals_jnp_miniresnet(data):
    x, *_ = data
    p = model.init_params(1)
    a = model.forward(p, x[:8])
    b = model.forward(p, x[:8], matmul=pallas_matmul)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_forward_pallas_equals_jnp_tinyvit(data):
    x, *_ = data
    p = vit.init_params(1)
    a = vit.forward(p, x[:8])
    b = vit.forward(p, x[:8], matmul=pallas_matmul)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_train_step_decreases_loss(data):
    x, y, _, _ = data
    p = model.init_params(2)
    p, losses = train.train(model.forward, p, x, y, lr=0.05, steps=60, batch=64)
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])


def test_training_reaches_good_accuracy(data):
    x, y, xt, yt = data
    p = model.init_params(3)
    p, _ = train.train(model.forward, p, x, y, lr=0.05, steps=150, batch=64)
    acc = train.accuracy(model.forward, p, xt, yt)
    assert acc > 0.85, acc


def test_untrained_accuracy_near_chance(data):
    _, _, xt, yt = data
    acc = train.accuracy(model.forward, model.init_params(4), xt, yt)
    assert acc < 0.35, acc


def test_cross_entropy_sane():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]])
    y = jnp.asarray([0.0, 1.0])
    assert float(train.cross_entropy(logits, y)) < 1e-3
    y_bad = jnp.asarray([1.0, 0.0])
    assert float(train.cross_entropy(logits, y_bad)) > 5.0
