"""pytest configuration: make `compile` importable when running from the
`python/` directory or the repo root."""

import sys
from pathlib import Path

PYTHON_DIR = Path(__file__).resolve().parent.parent
if str(PYTHON_DIR) not in sys.path:
    sys.path.insert(0, str(PYTHON_DIR))
