"""mdt container and dataset generator tests (Python side)."""

import numpy as np
import pytest

from compile import dataset, mdt


def test_mdt_roundtrip(tmp_path):
    p = tmp_path / "t.mdt"
    tensors = {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4) - 5.5,
        "b": np.asarray([1.0, -2.0], dtype=np.float32),
        "scalar3d": np.zeros((2, 1, 2), dtype=np.float32),
    }
    mdt.write_mdt(p, tensors)
    back = mdt.read_mdt(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_mdt_rejects_garbage(tmp_path):
    p = tmp_path / "bad.mdt"
    p.write_bytes(b"XXXX\x00\x00\x00\x00")
    with pytest.raises(ValueError):
        mdt.read_mdt(p)


def test_mdt_truncation_detected(tmp_path):
    p = tmp_path / "t.mdt"
    mdt.write_mdt(p, {"w": np.zeros((8, 8), dtype=np.float32)})
    data = p.read_bytes()
    p.write_bytes(data[:-5])
    with pytest.raises(ValueError):
        mdt.read_mdt(p)


def test_dataset_deterministic():
    x1, y1 = dataset.generate(32, 1.0, 9)
    x2, y2 = dataset.generate(32, 1.0, 9)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_dataset_proto_seed_shares_classes():
    # Same proto_seed -> same prototypes -> a nearest-prototype classifier
    # trained on split A classifies split B.
    xa, ya = dataset.generate(400, 0.5, 11)
    xb, yb = dataset.generate(400, 0.5, 12, proto_seed=11)
    protos = dataset.class_prototypes(11)
    pred = np.argmin(
        ((xb[:, None, :] - protos[None, :, :]) ** 2).sum(-1), axis=1
    )
    assert (pred == yb).mean() > 0.95
    # Different proto seed -> different classes.
    xc, yc = dataset.generate(400, 0.5, 12)
    pred_c = np.argmin(
        ((xc[:, None, :] - protos[None, :, :]) ** 2).sum(-1), axis=1
    )
    assert (pred_c == yc).mean() < 0.5


def test_xoshiro_below_in_range():
    rng = dataset.Xoshiro256(3)
    vals = [rng.below(10) for _ in range(1000)]
    assert min(vals) >= 0 and max(vals) <= 9
    assert len(set(vals)) == 10


def test_xoshiro_normal_moments():
    rng = dataset.Xoshiro256(4)
    xs = np.asarray([rng.normal() for _ in range(20000)])
    assert abs(xs.mean()) < 0.03
    assert abs(xs.std() - 1.0) < 0.03
